//! Chunk-granular discrete-event fabric simulator (the packet-level
//! [`FabricBackend`](super::FabricBackend)), in the style of the htsim
//! family of simulators: a single event queue over integer nanoseconds,
//! per-link FIFO queues with store-and-forward serialization, per-hop
//! propagation latency, and seeded round-robin endpoint injection.
//!
//! What it adds over the fluid engine — and the reason it exists — is
//! **queueing**: cells wait behind other cells, so incast, head-of-line
//! blocking and tail latency are first-class observations
//! ([`PacketSim::tail`]) instead of being fluid-averaged away.
//!
//! ## Model
//!
//! * Each [`Flow`] is carved into `n = ceil(bytes / cell_bytes)`
//!   equal-size **cells** (so byte conservation is exact), issued at
//!   `issue_t` + the same setup latency the fluid engine charges.
//! * Each **source GPU** owns an injector: a serializer at the per-GPU
//!   injection cap that round-robins across its flows (seeded initial
//!   rotation). Per flow, injection is additionally *paced* at the
//!   flow's rate ceiling (size efficiency × bottleneck × relay ρ — the
//!   same [`FabricParams::flow_rate_cap_gbps`] the fluid solver caps
//!   rates with), and *windowed*: at most `buffer_bytes` may be in
//!   flight per flow (the §IV-C P2P staging-buffer credit); the window
//!   reopens on delivery (credit return).
//! * Each **link** serializes the head of its FIFO at
//!   `min(link capacity, flow ceiling)`, then forwards the cell after
//!   `latency_ns` of propagation. Inter-node hops additionally charge
//!   the per-node NIC aggregate (the Fig 6b 170 GB/s anchor) as a
//!   serial token budget, so four 45.1 GB/s rails cannot exceed it.
//! * Each **destination GPU** drains arrivals through a receive stage
//!   at the HBM-write cap — the incast bottleneck.
//!
//! ## Event core (DESIGN.md §9)
//!
//! Every arbitration is deterministic: events are keyed by
//! `(time, insertion seq)` and ties never consult unordered state, so
//! identical seeds produce **byte-identical event traces**
//! (`prop_packet_identical_seeds_identical_traces` in
//! `tests/fabric_props.rs` holds this).
//!
//! The queue behind that key is selected by
//! [`SchedulerKind`](super::SchedulerKind):
//!
//! * `Heap` — the original `BinaryHeap<Reverse<(t, seq, ev)>>`,
//!   retained verbatim as the **equivalence oracle** (the same playbook
//!   as the planner's `SolverKind::Reference`): `O(log n)` per event
//!   with one global cache-hostile heap.
//! * `Wheel` (default) — the rebuilt fast path: a calendar-queue
//!   timing wheel ([`crate::util::eventq::WheelQueue`], amortized
//!   `O(1)`, allocation-free once warm) fronted by a one-slot **fast
//!   lane**: `schedule` keeps the earliest pending event in a register
//!   slot and `pop` takes it whenever it beats the wheel's head, so a
//!   busy link's service chain (completion → next service → …) elides
//!   the queue entirely when it is the next thing to happen. Both pop
//!   in identical `(time, seq)` order, so the two schedulers process
//!   the **same event sequence** — traces, results and tail stats are
//!   byte-identical (pinned across seeds × faults in
//!   `tests/fabric_props.rs`).
//!
//! Preemption ([`PacketSim::preempt`]) mirrors the fluid engine's
//! semantics: the flow freezes at the bytes *delivered* so far and the
//! caller re-issues the residual on new paths; cells still inside the
//! fabric are aborted at their next event (their traversed hops stay
//! charged to `link_bytes` — rerouting is not free).

use super::backend::{reduce_blame, BlameKey, FabricStall, TailStats, WindowAttr};
use super::faults;
use super::fluid::{Flow, FlowResult, SimResult};
use super::{FabricParams, SchedulerKind};
use crate::topology::Topology;
use crate::util::eventq::{EventQueue, HeapQueue, WheelQueue};
use crate::util::hist::LatencyHist;
use crate::util::rng::{stream_seed, Rng};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Trace record: `(time_ns, code, a, b)` with the `TRACE_*` codes.
pub type TraceEvent = (u64, u8, u32, u32);

/// Trace code: cell `(flow a, cell b)` finished injector serialization.
pub const TRACE_INJECT: u8 = 1;
/// Trace code: link `a` finished serializing a cell of flow `b`.
pub const TRACE_LINK_DONE: u8 = 2;
/// Trace code: cell `(flow a, cell b)` delivered end-to-end.
pub const TRACE_DELIVER: u8 = 3;

/// Discrete events. Queue order is `(time, seq)`; the derived `Ord` on
/// the payload exists only to satisfy the retained heap's type bounds
/// (`seq` is unique, so the payload never decides order). `Enq`
/// carries the cell's hop position so handlers never re-scan the path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// Injector of GPU `g` may be free — attempt the next injection.
    Inject(u32),
    /// Cell `(flow, idx)` arrives at hop `pos`'s link input queue.
    Enq(u32, u32, u32, u8),
    /// Link may complete its in-service cell and/or start the next.
    LinkTick(u32),
    /// Cell `(flow, idx)` arrives at GPU `g`'s receive stage.
    RecvEnq(u32, u32, u32),
    /// Receive stage of GPU `g` may complete and/or start the next.
    RecvTick(u32),
}

/// Virtual time in integer nanoseconds (1 GB/s ≡ 1 byte/ns, so rate
/// arithmetic needs no unit constants).
pub(crate) fn ns_of(t_s: f64) -> u64 {
    if t_s <= 0.0 {
        0
    } else {
        (t_s * 1e9).round() as u64
    }
}

/// Serialization time of `bytes` at `gbps`, in whole nanoseconds
/// (ceiling, minimum 1 ns so zero-duration service loops are
/// impossible).
fn dur_ns(bytes: f64, gbps: f64) -> u64 {
    debug_assert!(gbps > 0.0, "non-positive rate");
    (bytes / gbps).ceil().max(1.0) as u64
}

/// The scheduler container: retained oracle heap or the rebuilt wheel.
enum SchedQueue {
    Heap(HeapQueue<Ev>),
    Wheel(WheelQueue<Ev>),
}

/// The packet-level discrete-event simulator. Construct with the full
/// initial flow set; drive through the [`FabricBackend`](super::FabricBackend)
/// surface (`advance_to` / `take_window` / `preempt` / `add_flows`).
pub struct PacketSim<'a> {
    topo: &'a Topology,
    params: FabricParams,
    // ---- per-flow state (issue order, like the fluid engine) ----
    flows: Vec<Flow>,
    start_t: Vec<f64>,
    t0_ns: Vec<u64>,
    cell_size: Vec<f64>,
    n_cells: Vec<u32>,
    injected: Vec<u32>,
    delivered: Vec<u32>,
    delivered_bytes: Vec<f64>,
    inflight_bytes: Vec<f64>,
    next_inject_ns: Vec<u64>,
    alive: Vec<bool>,
    preempted: Vec<bool>,
    /// `u64::MAX` while the flow is in flight.
    finish_ns: Vec<u64>,
    flow_cap_gbps: Vec<f64>,
    window_cap: Vec<f64>,
    /// Hop-0 enqueue timestamps, FIFO per flow (cells of one flow
    /// deliver in order, so transit latency pairs up by popping).
    enq0_q: Vec<VecDeque<u64>>,
    /// Position of the flow within `flows_at[src]` (the RR index the
    /// injector's open-set arithmetic runs on).
    inj_pos: Vec<u32>,
    /// Slot into `pair_keys`/`pair_hist` (resolved once at add time so
    /// the delivery hot path never walks a map).
    pair_slot: Vec<u32>,
    tag_slot: Vec<u32>,
    unfinished: usize,
    // ---- per-source-GPU injectors ----
    flows_at: Vec<Vec<u32>>,
    /// Injectable flows per GPU, by position in `flows_at`: alive, not
    /// fully injected, window open. Maintained incrementally so the RR
    /// scan skips closed/done flows instead of iterating all of them
    /// (pure strength reduction: the chosen flow and the computed wake
    /// are identical to the full scan's, which only ever collected
    /// wake times from open flows).
    open: Vec<BTreeSet<u32>>,
    rr: Vec<usize>,
    inj_busy_until: Vec<u64>,
    // ---- per-link queues + servers ----
    lq: Vec<VecDeque<(u32, u32, u8)>>,
    lq_bytes: Vec<f64>,
    peak_lq_bytes: Vec<f64>,
    /// `(flow, cell idx, hop pos, completion time)` of the cell in
    /// service.
    in_service: Vec<Option<(u32, u32, u8, u64)>>,
    link_rate: Vec<f64>,
    /// Per-link capacity scale under faults (1 healthy, 0 dead: the
    /// queue freezes until a restore event re-kicks the server).
    /// Scaling by exactly 1.0 is a bit-exact no-op, so fault-free runs
    /// keep byte-identical traces.
    link_scale: Vec<f64>,
    /// Per-GPU injector-serializer scale under faults (stragglers).
    inject_scale: Vec<f64>,
    /// Node whose NIC-out (resp. NIC-in) aggregate a cell on this link
    /// charges; `u32::MAX` = none. On flat fabrics every non-NVLink
    /// link charges both endpoints' nodes (the old `is_net` rule); on
    /// tiered fabrics leaf uplinks charge the source node's out clock,
    /// leaf downlinks the destination node's in clock, and spine links
    /// neither (switch-to-switch hops cross no host NIC).
    charge_out_node: Vec<u32>,
    charge_in_node: Vec<u32>,
    // ---- per-node NIC-aggregate token clocks ----
    net_out_free: Vec<u64>,
    net_in_free: Vec<u64>,
    // ---- per-destination-GPU receive stages ----
    rq: Vec<VecDeque<(u32, u32)>>,
    rq_bytes: Vec<f64>,
    peak_rq_bytes: Vec<f64>,
    r_in_service: Vec<Option<(u32, u32, u64)>>,
    // ---- accounting ----
    link_bytes: Vec<f64>,
    /// Per-flow, per-hop-position bytes completed since the last
    /// window drain. Cells can be fractional (`bytes / n_cells`), so
    /// the per-link window totals are recovered through the canonical
    /// blame reduction ([`reduce_blame`], DESIGN.md §16) — the same
    /// code path whether or not attribution is requested.
    win_hop: Vec<Vec<f64>>,
    sojourn: LatencyHist,
    transit: LatencyHist,
    /// Exact per-chunk samples, recorded only under
    /// `PacketParams::exact_tail` (the histogram oracle).
    sojourn_exact_s: Vec<f64>,
    transit_exact_s: Vec<f64>,
    /// Distinct (src, dst) pairs / tags in first-seen order; latencies
    /// land in the parallel `*_hist` histograms and are only assembled
    /// into sorted maps by [`PacketSim::tail`].
    pair_keys: Vec<(usize, usize)>,
    pair_hist: Vec<LatencyHist>,
    pair_slot_of: BTreeMap<(usize, usize), u32>,
    tag_keys: Vec<u64>,
    tag_hist: Vec<LatencyHist>,
    tag_slot_of: BTreeMap<u64, u32>,
    // ---- event core ----
    queue: SchedQueue,
    /// One-slot fast lane (wheel scheduler only): the earliest event
    /// seen since the last fast-lane pop. Never used by the retained
    /// heap, which must stay the verbatim original engine.
    fast: Option<(u64, u64, Ev)>,
    seq: u64,
    t_ns: u64,
    events: u64,
    trace_on: bool,
    trace: Vec<TraceEvent>,
}

impl<'a> PacketSim<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams, flows: &[Flow]) -> Self {
        let nl = topo.links.len();
        let ng = topo.num_gpus();
        let nn = topo.nodes;
        let queue = match params.packet.scheduler {
            SchedulerKind::Heap => SchedQueue::Heap(HeapQueue::new()),
            SchedulerKind::Wheel => SchedQueue::Wheel(WheelQueue::new()),
        };
        let mut sim = PacketSim {
            topo,
            flows: Vec::new(),
            start_t: Vec::new(),
            t0_ns: Vec::new(),
            cell_size: Vec::new(),
            n_cells: Vec::new(),
            injected: Vec::new(),
            delivered: Vec::new(),
            delivered_bytes: Vec::new(),
            inflight_bytes: Vec::new(),
            next_inject_ns: Vec::new(),
            alive: Vec::new(),
            preempted: Vec::new(),
            finish_ns: Vec::new(),
            flow_cap_gbps: Vec::new(),
            window_cap: Vec::new(),
            enq0_q: Vec::new(),
            inj_pos: Vec::new(),
            pair_slot: Vec::new(),
            tag_slot: Vec::new(),
            unfinished: 0,
            flows_at: vec![Vec::new(); ng],
            open: vec![BTreeSet::new(); ng],
            rr: (0..ng)
                .map(|g| {
                    // seeded initial rotation, reduced modulo the live
                    // flow count at pick time
                    Rng::new(stream_seed(params.packet.seed, g as u64)).next_u64()
                        as usize
                })
                .collect(),
            inj_busy_until: vec![0; ng],
            lq: vec![VecDeque::new(); nl],
            lq_bytes: vec![0.0; nl],
            peak_lq_bytes: vec![0.0; nl],
            in_service: vec![None; nl],
            link_rate: topo.links.iter().map(|l| l.cap_gbps).collect(),
            link_scale: vec![1.0; nl],
            inject_scale: vec![1.0; ng],
            charge_out_node: topo
                .links
                .iter()
                .map(|l| topo.nic_out_node(l).map_or(u32::MAX, |n| n as u32))
                .collect(),
            charge_in_node: topo
                .links
                .iter()
                .map(|l| topo.nic_in_node(l).map_or(u32::MAX, |n| n as u32))
                .collect(),
            net_out_free: vec![0; nn],
            net_in_free: vec![0; nn],
            rq: vec![VecDeque::new(); ng],
            rq_bytes: vec![0.0; ng],
            peak_rq_bytes: vec![0.0; ng],
            r_in_service: vec![None; ng],
            link_bytes: vec![0.0; nl],
            win_hop: Vec::new(),
            sojourn: LatencyHist::new(),
            transit: LatencyHist::new(),
            sojourn_exact_s: Vec::new(),
            transit_exact_s: Vec::new(),
            pair_keys: Vec::new(),
            pair_hist: Vec::new(),
            pair_slot_of: BTreeMap::new(),
            tag_keys: Vec::new(),
            tag_hist: Vec::new(),
            tag_slot_of: BTreeMap::new(),
            queue,
            fast: None,
            seq: 0,
            t_ns: 0,
            events: 0,
            trace_on: false,
            trace: Vec::new(),
            params,
        };
        sim.add_flows(flows);
        sim
    }

    /// Record the compact event trace (determinism property tests);
    /// off by default to bound memory.
    pub fn set_trace(&mut self, on: bool) {
        self.trace_on = on;
    }

    /// The recorded trace (empty unless [`PacketSim::set_trace`]).
    pub fn trace(&self) -> &[TraceEvent] {
        &self.trace
    }

    /// Events processed so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Self-profiling counters: every `schedule()` bumps `seq` (one
    /// scheduler push), every processed event is one pop — so both are
    /// derivable with zero extra bookkeeping in the hot loop.
    pub fn profile(&self) -> crate::fabric::backend::EngineProfile {
        crate::fabric::backend::EngineProfile {
            events: self.events,
            sched_pushes: self.seq,
            sched_pops: self.events,
            solver_invocations: 0,
        }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.t_ns as f64 * 1e-9
    }

    /// All flows delivered or preempted.
    pub fn is_done(&self) -> bool {
        self.unfinished == 0
    }

    /// Bytes flow `i` still has to deliver (0 once finished/preempted).
    pub fn residual_bytes(&self, i: usize) -> f64 {
        if self.finish_ns[i] == u64::MAX {
            (self.flows[i].bytes.max(1.0) - self.delivered_bytes[i]).max(0.0)
        } else {
            0.0
        }
    }

    /// Bytes flow `i` has delivered end-to-end so far.
    pub fn moved_bytes(&self, i: usize) -> f64 {
        self.delivered_bytes[i]
    }

    /// Whether flow `i` is still in flight.
    pub fn is_live(&self, i: usize) -> bool {
        self.finish_ns[i] == u64::MAX
    }

    /// The flow registered under index `i` (issue order).
    pub fn flow(&self, i: usize) -> &Flow {
        &self.flows[i]
    }

    /// Flows registered so far.
    pub fn num_flows(&self) -> usize {
        self.flows.len()
    }

    /// Cells flow `i` was carved into (equal-size, `bytes / cells`).
    pub fn cells_of(&self, i: usize) -> u32 {
        self.n_cells[i]
    }

    /// Register additional flows; returns the first new index.
    pub fn add_flows(&mut self, flows: &[Flow]) -> usize {
        let first = self.flows.len();
        for f in flows {
            let i = self.flows.len();
            let start_s = f.issue_t + self.params.start_latency_s(&f.path, f.mode);
            let bytes = f.bytes.max(1.0);
            let n = (bytes / self.params.packet.cell_bytes.max(1.0)).ceil().max(1.0);
            let cell = bytes / n;
            let cap = (self.params.flow_rate_cap_gbps(self.topo, &f.path, f.bytes)
                * f.rate_factor)
                .max(1e-3);
            debug_assert!(f.path.hops.len() < u8::MAX as usize, "path too deep");
            self.start_t.push(start_s);
            self.t0_ns.push(ns_of(start_s));
            self.cell_size.push(cell);
            self.n_cells.push(n as u32);
            self.injected.push(0);
            self.delivered.push(0);
            self.delivered_bytes.push(0.0);
            self.inflight_bytes.push(0.0);
            self.next_inject_ns.push(0);
            self.alive.push(true);
            self.preempted.push(false);
            self.finish_ns.push(u64::MAX);
            self.flow_cap_gbps.push(cap);
            self.window_cap.push(self.params.packet.buffer_bytes.max(cell));
            self.enq0_q.push(VecDeque::new());
            let pos = self.flows_at[f.path.src].len() as u32;
            self.inj_pos.push(pos);
            let pair = (f.path.src, f.path.dst);
            let ps = *self.pair_slot_of.entry(pair).or_insert_with(|| {
                self.pair_keys.push(pair);
                self.pair_hist.push(LatencyHist::new());
                (self.pair_keys.len() - 1) as u32
            });
            self.pair_slot.push(ps);
            let ts = *self.tag_slot_of.entry(f.tag).or_insert_with(|| {
                self.tag_keys.push(f.tag);
                self.tag_hist.push(LatencyHist::new());
                (self.tag_keys.len() - 1) as u32
            });
            self.tag_slot.push(ts);
            self.win_hop.push(vec![0.0; f.path.hops.len()]);
            self.flows_at[f.path.src].push(i as u32);
            self.open[f.path.src].insert(pos);
            self.unfinished += 1;
            let wake = self.t0_ns[i].max(self.t_ns);
            self.schedule(wake, Ev::Inject(f.path.src as u32));
            self.flows.push(f.clone());
        }
        first
    }

    /// Preempt flow `i`: freeze it at the bytes delivered so far and
    /// return the residual for re-issue. Cells still inside the fabric
    /// are aborted at their next event.
    pub fn preempt(&mut self, i: usize) -> f64 {
        if self.finish_ns[i] != u64::MAX {
            return 0.0;
        }
        let residual = self.residual_bytes(i);
        self.alive[i] = false;
        self.preempted[i] = true;
        self.finish_ns[i] = self.t_ns;
        self.inflight_bytes[i] = 0.0;
        self.open[self.flows[i].path.src].remove(&self.inj_pos[i]);
        self.unfinished -= 1;
        residual
    }

    /// Apply a fault: a dead link freezes its queue (the in-service
    /// cell still completes — it was already on the wire — but nothing
    /// new enters service); degraded links and straggling injectors
    /// serialize slower from their next cell on; restore events
    /// re-kick frozen servers (the kick goes through [`Self::schedule`],
    /// so it lands in whichever scheduler is active — the wheel's
    /// cursor-bucket heap accepts events at the current time directly).
    /// Fault-free runs never call this, so their event traces stay
    /// byte-identical.
    pub fn apply_fault(&mut self, fault: &faults::Fault) {
        let t = self.t_ns;
        match *fault {
            faults::Fault::LinkDown { link } => self.link_scale[link] = 0.0,
            faults::Fault::LinkUp { link } => {
                self.link_scale[link] = 1.0;
                self.schedule(t, Ev::LinkTick(link as u32));
            }
            faults::Fault::RailDegraded { rail, factor } => {
                for l in faults::rail_links(self.topo, rail) {
                    let was_dead = self.link_scale[l] <= 0.0;
                    self.link_scale[l] = factor;
                    if was_dead {
                        self.schedule(t, Ev::LinkTick(l as u32));
                    }
                }
            }
            faults::Fault::StragglerNode { node, inject_factor } => {
                for local in 0..self.topo.gpus_per_node {
                    let g = self.topo.gpu(node, local);
                    self.inject_scale[g] = inject_factor;
                }
                for l in faults::node_out_links(self.topo, node) {
                    let was_dead = self.link_scale[l] <= 0.0;
                    self.link_scale[l] = inject_factor;
                    if was_dead {
                        self.schedule(t, Ev::LinkTick(l as u32));
                    }
                }
            }
        }
    }

    /// Bucket this window's per-flow, per-hop byte counters per link by
    /// (tag, src, dst) and reset them — the shared reduction behind
    /// both window drains, so their totals are bit-identical.
    fn window_attr(&mut self) -> WindowAttr {
        let mut per_link: Vec<BTreeMap<BlameKey, f64>> =
            vec![BTreeMap::new(); self.link_bytes.len()];
        for (i, f) in self.flows.iter().enumerate() {
            let key = (f.tag, f.path.src, f.path.dst);
            for (pos, &h) in f.path.hops.iter().enumerate() {
                let w = self.win_hop[i][pos];
                if w == 0.0 {
                    continue;
                }
                *per_link[h].entry(key).or_insert(0.0) += w;
            }
        }
        for v in &mut self.win_hop {
            for w in v.iter_mut() {
                *w = 0.0;
            }
        }
        reduce_blame(per_link)
    }

    /// Per-link bytes serialized since the previous call; resets the
    /// window counters (the monitor's sampling surface).
    pub fn take_window(&mut self) -> Vec<f64> {
        self.window_attr().totals
    }

    /// [`PacketSim::take_window`] plus the per-link (tag, src, dst)
    /// blame decomposition; totals carry the identical bits.
    pub fn take_window_attr(&mut self) -> WindowAttr {
        self.window_attr()
    }

    /// Advance the event loop until `t_stop` (a replan epoch boundary)
    /// or until every flow completes, whichever comes first.
    ///
    /// An unbounded advance (`t_stop` non-finite) with live flows but
    /// an empty event queue cannot make progress — a zero-capacity
    /// misconfiguration or an un-restored dead link — and reports
    /// [`FabricStall`] instead of panicking.
    pub fn advance_to(&mut self, t_stop: f64) -> Result<(), FabricStall> {
        let stop_ns = if t_stop.is_finite() { ns_of(t_stop) } else { u64::MAX };
        while self.unfinished > 0 {
            let Some(t) = self.peek_time() else {
                if stop_ns == u64::MAX {
                    return Err(FabricStall {
                        live_flows: self.unfinished,
                        t_s: self.now(),
                    });
                }
                break;
            };
            if t > stop_ns {
                break;
            }
            let (t, _, ev) = self.pop_event().expect("peeked");
            self.t_ns = t;
            self.events += 1;
            match ev {
                Ev::Inject(g) => self.injector_tick(g as usize, t),
                Ev::Enq(l, f, idx, pos) => {
                    self.enqueue_link(l as usize, f as usize, idx, pos, t)
                }
                Ev::LinkTick(l) => self.link_tick(l as usize, t),
                Ev::RecvEnq(g, f, idx) => {
                    self.enqueue_recv(g as usize, f as usize, idx, t)
                }
                Ev::RecvTick(g) => self.recv_tick(g as usize, t),
            }
        }
        if stop_ns != u64::MAX && stop_ns > self.t_ns {
            self.t_ns = stop_ns;
        }
        Ok(())
    }

    /// Run every remaining event (no epoch bound).
    pub fn run_to_completion(&mut self) -> Result<(), FabricStall> {
        self.advance_to(f64::INFINITY)
    }

    /// Snapshot the outcome in the same shape as the fluid engine:
    /// preempted flows report the bytes they actually delivered, so a
    /// preempted original and its re-issued residuals sum to the
    /// payload without double counting.
    pub fn result(&self) -> SimResult {
        let flows: Vec<FlowResult> = (0..self.flows.len())
            .map(|i| FlowResult {
                start_t: self.start_t[i],
                finish_t: if self.finish_ns[i] == u64::MAX {
                    f64::NAN
                } else {
                    self.finish_ns[i] as f64 * 1e-9
                },
                bytes: if self.preempted[i] {
                    self.delivered_bytes[i]
                } else {
                    self.flows[i].bytes
                },
            })
            .collect();
        let makespan = flows
            .iter()
            .map(|f| f.finish_t)
            .filter(|t| !t.is_nan())
            .fold(0.0, f64::max);
        SimResult { flows, link_bytes: self.link_bytes.clone(), makespan }
    }

    /// The latency/queue-depth observations this backend exists for.
    /// The sorted per-pair/per-tag maps are assembled here, off the
    /// hot path; deliveries only push into slot-indexed vectors.
    pub fn tail(&self) -> TailStats {
        let mut per_pair = BTreeMap::new();
        for (k, h) in self.pair_keys.iter().zip(&self.pair_hist) {
            per_pair.insert(*k, h.clone());
        }
        let mut per_tag: BTreeMap<u64, LatencyHist> = BTreeMap::new();
        for (k, h) in self.tag_keys.iter().zip(&self.tag_hist) {
            per_tag.entry(*k).or_default().merge(h);
        }
        TailStats {
            sojourn: self.sojourn.clone(),
            transit: self.transit.clone(),
            per_pair_sojourn: per_pair,
            per_tag_sojourn: per_tag,
            peak_queue_bytes: self.peak_lq_bytes.clone(),
            peak_recv_queue_bytes: self.peak_rq_bytes.clone(),
            delivered_chunks: self.sojourn.total(),
            sojourn_exact_s: self.sojourn_exact_s.clone(),
            transit_exact_s: self.transit_exact_s.clone(),
        }
    }

    // ---- partitioned-engine support (`packet_par`) ----

    /// Internal clock in integer nanoseconds (exact, unlike `now`).
    pub(crate) fn clock_ns(&self) -> u64 {
        self.t_ns
    }

    /// Align a freshly created, still-empty sub-simulation's clock with
    /// the partitioned wrapper's epoch, so flows added next compute the
    /// same `max(t0, now)` wake a monolithic engine would.
    pub(crate) fn warp_clock_ns(&mut self, t_ns: u64) {
        debug_assert!(
            self.flows.is_empty() && self.events == 0,
            "clock warp on a sim that already ran"
        );
        self.t_ns = self.t_ns.max(t_ns);
    }

    /// Transplant `other`'s entire state into `self` (partition merge:
    /// a new flow bridges two previously node-disjoint components).
    /// Returns the local index offset added to `other`'s flows.
    ///
    /// Correctness rests on disjointness: the two components share no
    /// GPU, link or charged NIC node, so per-resource state moves over
    /// without collision (debug-asserted). `other`'s pending events are
    /// re-pushed in `(t, seq)` order with fresh sequence numbers — all
    /// of them lie at or after the common epoch time, so the merged
    /// queue never schedules into the past. Observation vectors append
    /// in victim order; the partitioned wrapper's canonical merge
    /// order makes the result independent of thread count.
    pub(crate) fn absorb(&mut self, mut other: PacketSim<'a>) -> u32 {
        let base = self.flows.len() as u32;
        self.t_ns = self.t_ns.max(other.t_ns);
        // 1) drain the victim's event queue (incl. its fast slot) in
        // key order, remapping flow ids into the merged index space
        while let Some((t, _, ev)) = other.pop_event() {
            let ev = match ev {
                Ev::Enq(l, f, idx, pos) => Ev::Enq(l, f + base, idx, pos),
                Ev::RecvEnq(g, f, idx) => Ev::RecvEnq(g, f + base, idx),
                e => e,
            };
            self.schedule(t, ev);
        }
        // 2) observation-table slots: re-resolve the victim's pair/tag
        // keys in the merged tables, then merge its latency histograms
        // (exact bucket-count addition, so merge order cannot matter)
        let pair_remap: Vec<u32> = other
            .pair_keys
            .iter()
            .map(|&k| {
                *self.pair_slot_of.entry(k).or_insert_with(|| {
                    self.pair_keys.push(k);
                    self.pair_hist.push(LatencyHist::new());
                    (self.pair_keys.len() - 1) as u32
                })
            })
            .collect();
        for (slot, h) in pair_remap.iter().zip(std::mem::take(&mut other.pair_hist)) {
            self.pair_hist[*slot as usize].merge(&h);
        }
        let tag_remap: Vec<u32> = other
            .tag_keys
            .iter()
            .map(|&k| {
                *self.tag_slot_of.entry(k).or_insert_with(|| {
                    self.tag_keys.push(k);
                    self.tag_hist.push(LatencyHist::new());
                    (self.tag_keys.len() - 1) as u32
                })
            })
            .collect();
        for (slot, h) in tag_remap.iter().zip(std::mem::take(&mut other.tag_hist)) {
            self.tag_hist[*slot as usize].merge(&h);
        }
        // 3) per-flow state, in the victim's local order
        for s in std::mem::take(&mut other.pair_slot) {
            self.pair_slot.push(pair_remap[s as usize]);
        }
        for s in std::mem::take(&mut other.tag_slot) {
            self.tag_slot.push(tag_remap[s as usize]);
        }
        self.flows.extend(std::mem::take(&mut other.flows));
        self.start_t.extend(std::mem::take(&mut other.start_t));
        self.t0_ns.extend(std::mem::take(&mut other.t0_ns));
        self.cell_size.extend(std::mem::take(&mut other.cell_size));
        self.n_cells.extend(std::mem::take(&mut other.n_cells));
        self.injected.extend(std::mem::take(&mut other.injected));
        self.delivered.extend(std::mem::take(&mut other.delivered));
        self.delivered_bytes.extend(std::mem::take(&mut other.delivered_bytes));
        self.inflight_bytes.extend(std::mem::take(&mut other.inflight_bytes));
        self.next_inject_ns.extend(std::mem::take(&mut other.next_inject_ns));
        self.alive.extend(std::mem::take(&mut other.alive));
        self.preempted.extend(std::mem::take(&mut other.preempted));
        self.finish_ns.extend(std::mem::take(&mut other.finish_ns));
        self.flow_cap_gbps.extend(std::mem::take(&mut other.flow_cap_gbps));
        self.window_cap.extend(std::mem::take(&mut other.window_cap));
        self.enq0_q.extend(std::mem::take(&mut other.enq0_q));
        self.inj_pos.extend(std::mem::take(&mut other.inj_pos));
        self.win_hop.extend(std::mem::take(&mut other.win_hop));
        self.unfinished += other.unfinished;
        // 4) per-GPU injector + receive state
        for g in 0..self.rr.len() {
            if !other.flows_at[g].is_empty() {
                debug_assert!(self.flows_at[g].is_empty(), "components share GPU {g}");
                self.flows_at[g] =
                    other.flows_at[g].drain(..).map(|f| f + base).collect();
                self.open[g] = std::mem::take(&mut other.open[g]);
                self.rr[g] = other.rr[g];
                self.inj_busy_until[g] = other.inj_busy_until[g];
            }
            if !other.rq[g].is_empty() || other.r_in_service[g].is_some() {
                debug_assert!(
                    self.rq[g].is_empty() && self.r_in_service[g].is_none(),
                    "components share receive stage {g}"
                );
                self.rq[g] = other.rq[g].drain(..).map(|(f, i)| (f + base, i)).collect();
                self.r_in_service[g] =
                    other.r_in_service[g].map(|(f, i, d)| (f + base, i, d));
            }
            self.rq_bytes[g] += other.rq_bytes[g];
            self.peak_rq_bytes[g] = self.peak_rq_bytes[g].max(other.peak_rq_bytes[g]);
        }
        // 5) per-link queues, servers and byte counters
        for l in 0..self.lq.len() {
            if !other.lq[l].is_empty() || other.in_service[l].is_some() {
                debug_assert!(
                    self.lq[l].is_empty() && self.in_service[l].is_none(),
                    "components share link {l}"
                );
                self.lq[l] =
                    other.lq[l].drain(..).map(|(f, i, p)| (f + base, i, p)).collect();
                self.in_service[l] =
                    other.in_service[l].map(|(f, i, p, d)| (f + base, i, p, d));
            }
            self.lq_bytes[l] += other.lq_bytes[l];
            self.peak_lq_bytes[l] = self.peak_lq_bytes[l].max(other.peak_lq_bytes[l]);
            self.link_bytes[l] += other.link_bytes[l];
        }
        // 6) per-node NIC token clocks (disjoint charge sets: max = move)
        for n in 0..self.net_out_free.len() {
            self.net_out_free[n] = self.net_out_free[n].max(other.net_out_free[n]);
            self.net_in_free[n] = self.net_in_free[n].max(other.net_in_free[n]);
        }
        // 7) merged observations + counters
        self.sojourn.merge(&other.sojourn);
        self.transit.merge(&other.transit);
        self.sojourn_exact_s.extend(std::mem::take(&mut other.sojourn_exact_s));
        self.transit_exact_s.extend(std::mem::take(&mut other.transit_exact_s));
        self.trace.extend(std::mem::take(&mut other.trace));
        self.events += other.events;
        base
    }

    // ---- internals ----

    fn schedule(&mut self, t: u64, ev: Ev) {
        self.seq += 1;
        match &mut self.queue {
            SchedQueue::Heap(q) => q.push(t, self.seq, ev),
            SchedQueue::Wheel(q) => {
                // fast lane: keep the earlier of (incoming, held) in
                // the slot, spill the other into the wheel. pop_event
                // compares the slot against the wheel head, so the
                // processed sequence is exactly (time, seq) order.
                match self.fast {
                    None => self.fast = Some((t, self.seq, ev)),
                    Some((ft, fs, fev)) if (t, self.seq) < (ft, fs) => {
                        q.push(ft, fs, fev);
                        self.fast = Some((t, self.seq, ev));
                    }
                    Some(_) => q.push(t, self.seq, ev),
                }
            }
        }
    }

    fn peek_time(&mut self) -> Option<u64> {
        match &mut self.queue {
            SchedQueue::Heap(q) => q.peek_key().map(|(t, _)| t),
            SchedQueue::Wheel(q) => match (self.fast, q.peek_key()) {
                (Some((ft, fs, _)), Some((qt, qs))) => {
                    Some(if (qt, qs) < (ft, fs) { qt } else { ft })
                }
                (Some((ft, _, _)), None) => Some(ft),
                (None, Some((qt, _))) => Some(qt),
                (None, None) => None,
            },
        }
    }

    fn pop_event(&mut self) -> Option<(u64, u64, Ev)> {
        match &mut self.queue {
            SchedQueue::Heap(q) => q.pop(),
            SchedQueue::Wheel(q) => match self.fast {
                Some((ft, fs, _)) => match q.peek_key() {
                    Some(k) if k < (ft, fs) => q.pop(),
                    _ => self.fast.take(),
                },
                None => q.pop(),
            },
        }
    }

    fn push_trace(&mut self, t: u64, code: u8, a: u32, b: u32) {
        if self.trace_on {
            self.trace.push((t, code, a, b));
        }
    }

    /// Re-derive flow `f`'s membership in its source's injectable set
    /// after a state transition (injection, credit return, preempt).
    #[inline]
    fn refresh_open(&mut self, f: usize) {
        let g = self.flows[f].path.src;
        let pos = self.inj_pos[f];
        let open = self.alive[f]
            && self.injected[f] < self.n_cells[f]
            && self.inflight_bytes[f] + self.cell_size[f] <= self.window_cap[f] + 1e-9;
        if open {
            self.open[g].insert(pos);
        } else {
            self.open[g].remove(&pos);
        }
    }

    /// Injector of GPU `g` attempts one injection at time `t`.
    ///
    /// The candidate order is the original full round-robin scan over
    /// `flows_at[g]` starting at `rr[g]`; the open-set only removes
    /// flows that scan skipped without effect (dead, done, window
    /// closed), so the chosen flow and the earliest wake time are
    /// identical to the original scan's.
    fn injector_tick(&mut self, g: usize, t: u64) {
        if t < self.inj_busy_until[g] {
            return; // the completion tick will re-attempt
        }
        let len = self.flows_at[g].len();
        if len == 0 || self.open[g].is_empty() {
            return;
        }
        let start = (self.rr[g] % len) as u32;
        let mut chosen = None;
        let mut wake = u64::MAX;
        for &pos in self.open[g].range(start..).chain(self.open[g].range(..start)) {
            let f = self.flows_at[g][pos as usize] as usize;
            let ready = self.t0_ns[f].max(self.next_inject_ns[f]);
            if ready > t {
                wake = wake.min(ready);
                continue;
            }
            chosen = Some(pos as usize);
            break;
        }
        let Some(pos) = chosen else {
            if wake != u64::MAX {
                self.schedule(wake, Ev::Inject(g as u32));
            }
            return;
        };
        let f = self.flows_at[g][pos] as usize;
        self.rr[g] = (pos + 1) % len;
        let cell = self.cell_size[f];
        let dur = dur_ns(cell, self.params.inject_cap_gbps * self.inject_scale[g]);
        self.inj_busy_until[g] = t + dur;
        // token-bucket pacing at the flow's rate ceiling: deadlines
        // advance by one period per cell with at most one period of
        // banked credit, so injector-arbitration jitter delays cells
        // without compounding into a rate loss
        let period = dur_ns(cell, self.flow_cap_gbps[f]);
        self.next_inject_ns[f] =
            self.next_inject_ns[f].max(t.saturating_sub(period)) + period;
        let idx = self.injected[f];
        self.injected[f] += 1;
        self.inflight_bytes[f] += cell;
        self.refresh_open(f);
        self.push_trace(t, TRACE_INJECT, f as u32, idx);
        let hop0 = self.flows[f].path.hops[0] as u32;
        self.schedule(t + dur, Ev::Enq(hop0, f as u32, idx, 0));
        self.schedule(t + dur, Ev::Inject(g as u32));
    }

    /// Cell `(f, idx)` arrives at hop `pos`'s link `l` input queue.
    fn enqueue_link(&mut self, l: usize, f: usize, idx: u32, pos: u8, t: u64) {
        if !self.alive[f] {
            return; // aborted mid-flight by a preemption
        }
        if pos == 0 {
            self.enq0_q[f].push_back(t);
        }
        self.lq[l].push_back((f as u32, idx, pos));
        self.lq_bytes[l] += self.cell_size[f];
        if self.lq_bytes[l] > self.peak_lq_bytes[l] {
            self.peak_lq_bytes[l] = self.lq_bytes[l];
        }
        if self.in_service[l].is_none() {
            self.link_tick(l, t);
        }
    }

    /// Link `l` completes its in-service cell (if due) and starts the
    /// next one it can.
    fn link_tick(&mut self, l: usize, t: u64) {
        if let Some((fu, idx, pos, done)) = self.in_service[l] {
            if t < done {
                return; // stale tick; the completion tick is scheduled
            }
            self.in_service[l] = None;
            let f = fu as usize;
            let cell = self.cell_size[f];
            self.link_bytes[l] += cell;
            self.win_hop[f][pos as usize] += cell;
            self.push_trace(t, TRACE_LINK_DONE, l as u32, fu);
            if self.alive[f] {
                let arr = t + self.params.packet.latency_ns;
                let hops = &self.flows[f].path.hops;
                if (pos as usize) + 1 < hops.len() {
                    let next = hops[pos as usize + 1] as u32;
                    self.schedule(arr, Ev::Enq(next, fu, idx, pos + 1));
                } else {
                    let dst = self.flows[f].path.dst as u32;
                    self.schedule(arr, Ev::RecvEnq(dst, fu, idx));
                }
            }
        }
        if self.link_scale[l] <= 0.0 {
            return; // dead link: queue frozen until a restore re-kicks
        }
        loop {
            let Some(&(fu, idx, pos)) = self.lq[l].front() else { return };
            let f = fu as usize;
            if !self.alive[f] {
                self.lq[l].pop_front();
                self.lq_bytes[l] -= self.cell_size[f];
                continue;
            }
            let mut s = t;
            let co = self.charge_out_node[l];
            let ci = self.charge_in_node[l];
            if co != u32::MAX {
                s = s.max(self.net_out_free[co as usize]);
            }
            if ci != u32::MAX {
                s = s.max(self.net_in_free[ci as usize]);
            }
            if s > t {
                // NIC-aggregate tokens not yet available: retry then
                self.schedule(s, Ev::LinkTick(l as u32));
                return;
            }
            self.lq[l].pop_front();
            let cell = self.cell_size[f];
            self.lq_bytes[l] -= cell;
            let rate = (self.link_rate[l] * self.link_scale[l]).min(self.flow_cap_gbps[f]);
            let done = t + dur_ns(cell, rate);
            self.in_service[l] = Some((fu, idx, pos, done));
            if co != u32::MAX || ci != u32::MAX {
                let agg = dur_ns(cell, self.params.node_net_cap_gbps);
                if co != u32::MAX {
                    let sn = co as usize;
                    self.net_out_free[sn] = self.net_out_free[sn].max(t) + agg;
                }
                if ci != u32::MAX {
                    let dn = ci as usize;
                    self.net_in_free[dn] = self.net_in_free[dn].max(t) + agg;
                }
            }
            self.schedule(done, Ev::LinkTick(l as u32));
            return;
        }
    }

    /// Cell `(f, idx)` arrives at GPU `g`'s receive stage.
    fn enqueue_recv(&mut self, g: usize, f: usize, idx: u32, t: u64) {
        if !self.alive[f] {
            return;
        }
        self.rq[g].push_back((f as u32, idx));
        self.rq_bytes[g] += self.cell_size[f];
        if self.rq_bytes[g] > self.peak_rq_bytes[g] {
            self.peak_rq_bytes[g] = self.rq_bytes[g];
        }
        if self.r_in_service[g].is_none() {
            self.recv_tick(g, t);
        }
    }

    /// Receive stage of GPU `g` completes a delivery (if due) and
    /// starts draining the next arrival.
    fn recv_tick(&mut self, g: usize, t: u64) {
        if let Some((fu, idx, done)) = self.r_in_service[g] {
            if t < done {
                return;
            }
            self.r_in_service[g] = None;
            let f = fu as usize;
            if self.alive[f] {
                let cell = self.cell_size[f];
                self.delivered[f] += 1;
                self.delivered_bytes[f] += cell;
                self.inflight_bytes[f] = (self.inflight_bytes[f] - cell).max(0.0);
                self.refresh_open(f);
                let enq0 = self.enq0_q[f].pop_front().unwrap_or(self.t0_ns[f]);
                let sojourn_ns = t.saturating_sub(self.t0_ns[f]);
                let transit_ns = t.saturating_sub(enq0);
                self.sojourn.record_ns(sojourn_ns);
                self.transit.record_ns(transit_ns);
                self.pair_hist[self.pair_slot[f] as usize].record_ns(sojourn_ns);
                self.tag_hist[self.tag_slot[f] as usize].record_ns(sojourn_ns);
                if self.params.packet.exact_tail {
                    self.sojourn_exact_s.push(sojourn_ns as f64 * 1e-9);
                    self.transit_exact_s.push(transit_ns as f64 * 1e-9);
                }
                self.push_trace(t, TRACE_DELIVER, fu, idx);
                // credit return: the source may inject again
                let src = self.flows[f].path.src;
                let wake = t.max(self.inj_busy_until[src]);
                self.schedule(wake, Ev::Inject(src as u32));
                if self.delivered[f] == self.n_cells[f] {
                    self.finish_ns[f] = t;
                    self.unfinished -= 1;
                }
            }
        }
        loop {
            let Some(&(fu, idx)) = self.rq[g].front() else { return };
            let f = fu as usize;
            self.rq[g].pop_front();
            self.rq_bytes[g] -= self.cell_size[f];
            if !self.alive[f] {
                continue;
            }
            let done = t + dur_ns(self.cell_size[f], self.params.recv_cap_gbps);
            self.r_in_service[g] = Some((fu, idx, done));
            self.schedule(done, Ev::RecvTick(g as u32));
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;

    const MB: f64 = 1024.0 * 1024.0;

    fn run(topo: &Topology, flows: &[Flow]) -> (SimResult, TailStats) {
        let mut sim = PacketSim::new(topo, FabricParams::default(), flows);
        sim.run_to_completion().expect("no stall");
        (sim.result(), sim.tail())
    }

    /// Fig 6a anchor on the packet backend: a single direct NVLink
    /// flow saturates near 120 GB/s (agreement with the fluid engine
    /// is asserted tighter in `exp::xcheck`).
    #[test]
    fn direct_nvlink_saturates() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 1, false).remove(0);
        let (r, tail) = run(&t, &[Flow::new(p, 256.0 * MB)]);
        let bw = r.aggregate_gbps();
        assert!(bw > 112.0 && bw <= 120.0, "bw={bw}");
        assert_eq!(tail.delivered_chunks, 1024);
        // uncontended: transit stays near the serialization floor —
        // pacing keeps queues shallow
        let worst = tail.transit.max_ns() as f64 * 1e-9;
        assert!(worst < 100e-6, "uncontended transit ballooned: {worst}");
    }

    /// Fig 6a 3-path anchor: the per-GPU injection cap emerges from
    /// the injector serializer (no max-min solver involved).
    #[test]
    fn three_path_injection_cap_emerges() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let big = 128.0 * MB;
        let flows: Vec<Flow> =
            cands.iter().take(3).map(|p| Flow::new(p.clone(), big)).collect();
        let (r, _) = run(&t, &flows);
        let agg = 3.0 * big / r.makespan / 1e9;
        assert!((agg - 278.2).abs() < 14.0, "3-path agg={agg}");
    }

    /// Fig 6b anchor: four rails are clamped by the per-node NIC
    /// aggregate (170), not 4 × 45.1 = 180.4.
    #[test]
    fn four_rails_clamped_by_node_cap() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, t.gpu(1, 0), true);
        let big = 64.0 * MB;
        let flows: Vec<Flow> =
            cands.iter().map(|p| Flow::new(p.clone(), big)).collect();
        let (r, _) = run(&t, &flows);
        let agg = 4.0 * big / r.makespan / 1e9;
        assert!((agg - 170.0).abs() < 9.0, "4-rail agg={agg}");
        // single rail for contrast
        let (r1, _) = run(&t, &[Flow::new(cands[0].clone(), big)]);
        let bw1 = r1.aggregate_gbps();
        assert!((bw1 - 45.1).abs() < 2.5, "1-rail bw={bw1}");
    }

    /// Two equal flows over one link share it and finish together-ish;
    /// contention shows up as queueing the tail stats can see.
    #[test]
    fn fair_share_and_queueing_under_contention() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 1, false).remove(0);
        let flows =
            vec![Flow::new(p.clone(), 64.0 * MB), Flow::new(p.clone(), 64.0 * MB)];
        let mut params = FabricParams::default();
        params.packet.exact_tail = true; // paired per-chunk samples below
        let mut sim = PacketSim::new(&t, params, &flows);
        sim.run_to_completion().expect("no stall");
        let (r, tail) = (sim.result(), sim.tail());
        let skew = (r.flows[0].finish_t - r.flows[1].finish_t).abs();
        assert!(skew < 50e-6, "finish skew {skew}");
        let bw = r.aggregate_gbps();
        assert!(bw <= 120.0 + 1e-6 && bw > 108.0, "bw={bw}");
        // the shared link queued cells (the fluid model cannot see this)
        let peak = tail.peak_queue_bytes.iter().cloned().fold(0.0, f64::max);
        assert!(peak > 0.0, "contention produced no queueing");
        // sojourn includes source-side pacing; transit is within it
        // (per-chunk pairing needs the exact-vector debug oracle)
        assert_eq!(tail.sojourn_exact_s.len() as u64, tail.sojourn.total());
        for (s, tr) in tail.sojourn_exact_s.iter().zip(&tail.transit_exact_s) {
            assert!(tr <= s, "transit {tr} exceeds sojourn {s}");
        }
    }

    /// Byte conservation through every hop: each of a 2-hop path's
    /// links carries the full payload exactly once.
    #[test]
    fn per_hop_byte_conservation() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 1, true).remove(1); // 2-hop relay
        let bytes = 48.0 * MB;
        let (r, _) = run(&t, &[Flow::new(p, bytes)]);
        let total: f64 = r.link_bytes.iter().sum();
        assert!((total - 2.0 * bytes).abs() < 1.0, "total={total}");
    }

    /// Identical seeds ⇒ byte-identical event traces and results; the
    /// seed genuinely feeds arbitration (different seeds still conserve).
    #[test]
    fn seeded_determinism() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let flows = vec![
            Flow::new(cands[0].clone(), 16.0 * MB),
            Flow::new(cands[1].clone(), 8.0 * MB),
            Flow::new(cands[2].clone(), 8.0 * MB).at(0.0002),
        ];
        let drive = |seed: u64| {
            let mut params = FabricParams::default();
            params.packet.seed = seed;
            let mut sim = PacketSim::new(&t, params, &flows);
            sim.set_trace(true);
            sim.run_to_completion().expect("no stall");
            (sim.trace().to_vec(), sim.result(), sim.events())
        };
        let (tr_a, r_a, ev_a) = drive(7);
        let (tr_b, r_b, ev_b) = drive(7);
        assert_eq!(tr_a, tr_b, "same seed, different trace");
        assert_eq!(ev_a, ev_b);
        assert_eq!(r_a.makespan.to_bits(), r_b.makespan.to_bits());
        assert_eq!(r_a.link_bytes, r_b.link_bytes);
        let (_, r_c, _) = drive(8);
        let sum = |r: &SimResult| r.flows.iter().map(|f| f.bytes).sum::<f64>();
        assert!((sum(&r_a) - sum(&r_c)).abs() < 1.0, "seed changed physics");
    }

    /// The wheel scheduler processes the identical event sequence the
    /// retained heap oracle does: traces, results and event counts are
    /// byte-identical (the full matrix lives in `tests/fabric_props.rs`).
    #[test]
    fn wheel_matches_heap_oracle() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, t.gpu(1, 1), true);
        let flows = vec![
            Flow::new(cands[0].clone(), 24.0 * MB),
            Flow::new(cands[1].clone(), 12.0 * MB).at(0.0003),
            Flow::new(cands[2].clone(), 6.0 * MB).at(0.0005),
        ];
        let drive = |kind: SchedulerKind| {
            let mut params = FabricParams::default();
            params.packet.scheduler = kind;
            let mut sim = PacketSim::new(&t, params, &flows);
            sim.set_trace(true);
            sim.run_to_completion().expect("no stall");
            (sim.trace().to_vec(), sim.result(), sim.events(), sim.tail())
        };
        let (tr_w, r_w, ev_w, tail_w) = drive(SchedulerKind::Wheel);
        let (tr_h, r_h, ev_h, tail_h) = drive(SchedulerKind::Heap);
        assert_eq!(tr_w, tr_h, "wheel diverged from heap oracle");
        assert_eq!(ev_w, ev_h);
        assert_eq!(r_w.makespan.to_bits(), r_h.makespan.to_bits());
        assert_eq!(r_w.link_bytes, r_h.link_bytes);
        for (a, b) in r_w.flows.iter().zip(&r_h.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
        }
        assert_eq!(tail_w.sojourn, tail_h.sojourn);
        assert_eq!(tail_w.per_pair_sojourn, tail_h.per_pair_sojourn);
        assert_eq!(tail_w.peak_queue_bytes, tail_h.peak_queue_bytes);
    }

    /// Mid-flight preempt + re-issue conserves the stream payload and
    /// the re-issued path actually delivers the residual.
    #[test]
    fn preempt_and_reissue_conserves_bytes() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let bytes = 64.0 * MB;
        let mut sim = PacketSim::new(
            &t,
            FabricParams::default(),
            &[Flow::new(cands[0].clone(), bytes)],
        );
        sim.advance_to(0.0003).expect("no stall");
        assert!(!sim.is_done());
        let residual = sim.preempt(0);
        assert!(residual > 0.0 && residual < bytes, "residual={residual}");
        let moved = sim.moved_bytes(0);
        assert!((moved + residual - bytes).abs() < 1.0);
        sim.add_flows(&[Flow::new(cands[1].clone(), residual).at(sim.now())]);
        sim.run_to_completion().expect("no stall");
        let r = sim.result();
        let delivered: f64 = r.flows.iter().map(|f| f.bytes).sum();
        assert!((delivered - bytes).abs() < 1.0, "delivered={delivered}");
        assert!(r.flows[1].finish_t > r.flows[0].finish_t);
    }

    /// Epoch-sliced advancement processes the identical event sequence:
    /// results are bit-identical to one uninterrupted run, and window
    /// samples partition the cumulative link bytes.
    #[test]
    fn epoch_slicing_is_bit_identical_and_windows_partition() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, t.gpu(1, 1), true);
        let flows = vec![
            Flow::new(cands[0].clone(), 24.0 * MB),
            Flow::new(cands[1].clone(), 12.0 * MB).at(0.0004),
        ];
        let mut whole = PacketSim::new(&t, FabricParams::default(), &flows);
        whole.run_to_completion().expect("no stall");
        let rw = whole.result();

        let mut sliced = PacketSim::new(&t, FabricParams::default(), &flows);
        let mut summed = vec![0.0; t.links.len()];
        let mut epoch = 0.0002;
        while !sliced.is_done() {
            sliced.advance_to(epoch).expect("no stall");
            for (s, w) in summed.iter_mut().zip(sliced.take_window()) {
                *s += w;
            }
            epoch += 0.0002;
        }
        let rs = sliced.result();
        assert_eq!(rw.makespan.to_bits(), rs.makespan.to_bits());
        for (a, b) in rw.flows.iter().zip(&rs.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
        }
        assert_eq!(rw.link_bytes, rs.link_bytes);
        for (i, (&s, &tot)) in summed.iter().zip(&rs.link_bytes).enumerate() {
            assert!((s - tot).abs() < 1.0, "link {i}: windows {s} vs total {tot}");
        }
    }

    /// A dead link freezes delivery (in-flight cells drain, then
    /// nothing moves) until LinkUp re-kicks the server; the payload
    /// still lands in full.
    #[test]
    fn fault_flap_freezes_then_recovers() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0); // rail 0, single hop
        let link = p.hops[0];
        let bytes = 64.0 * MB;
        let mut sim =
            PacketSim::new(&t, FabricParams::default(), &[Flow::new(p, bytes)]);
        sim.advance_to(0.0003).expect("no stall");
        sim.apply_fault(&faults::Fault::LinkDown { link });
        sim.advance_to(0.0050).expect("no stall");
        assert!(!sim.is_done(), "flow finished across a dead link");
        let stalled = sim.moved_bytes(0);
        sim.advance_to(0.0060).expect("no stall");
        assert!(
            (sim.moved_bytes(0) - stalled).abs() < 1.0,
            "dead link kept delivering"
        );
        sim.apply_fault(&faults::Fault::LinkUp { link });
        sim.run_to_completion().expect("no stall");
        assert!(sim.is_done());
        let r = sim.result();
        assert!((r.flows[0].bytes - bytes).abs() < 1.0);
    }

    /// An unbounded run across a permanently dead link cannot make
    /// progress: it reports the typed stall instead of panicking.
    #[test]
    fn unbounded_run_over_dead_link_reports_stall() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0);
        let link = p.hops[0];
        let mut sim =
            PacketSim::new(&t, FabricParams::default(), &[Flow::new(p, 8.0 * MB)]);
        sim.apply_fault(&faults::Fault::LinkDown { link });
        let err = sim.run_to_completion().expect_err("dead link must stall");
        assert_eq!(err.live_flows, 1);
        assert!(!sim.is_done());
    }

    /// A degraded rail serializes slower: same payload, a multiple of
    /// the healthy makespan, bytes conserved.
    #[test]
    fn fault_degrade_slows_rail() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0);
        let bytes = 32.0 * MB;
        let fly = |fault: Option<faults::Fault>| {
            let mut sim = PacketSim::new(
                &t,
                FabricParams::default(),
                &[Flow::new(p.clone(), bytes)],
            );
            if let Some(f) = fault {
                sim.apply_fault(&f);
            }
            sim.run_to_completion().expect("no stall");
            sim.result().makespan
        };
        let healthy = fly(None);
        let degraded =
            fly(Some(faults::Fault::RailDegraded { rail: 0, factor: 0.25 }));
        assert!(
            degraded > 2.0 * healthy,
            "degrade had no effect: {degraded} vs {healthy}"
        );
    }

    /// Incast: 7 senders into one destination queue up at the receive
    /// stage; the tail observes it (p99 transit ≫ uncontended) while
    /// goodput stays near the receive cap.
    #[test]
    fn incast_shows_up_in_tail_latency() {
        let t = Topology::paper();
        let dst = 1usize;
        let flows: Vec<Flow> = (0..t.num_gpus())
            .filter(|&s| s != dst)
            .map(|s| Flow::new(candidates(&t, s, dst, false).remove(0), 16.0 * MB))
            .collect();
        let (r, tail) = run(&t, &flows);
        let payload = 7.0 * 16.0 * MB;
        let agg = payload / r.makespan / 1e9;
        assert!(agg < 278.2 + 1.0, "incast beat the receive cap: {agg}");
        let p99 = tail.transit.quantile_s(99.0);
        let p50 = tail.transit.quantile_s(50.0);
        assert!(p99 >= p50, "percentiles out of order");
        let peak_rq = tail.peak_recv_queue_bytes[dst];
        assert!(peak_rq > 0.0, "incast produced no receive-side queueing");
    }
}
