//! Chunk-granular discrete-event fabric simulator (the packet-level
//! [`FabricBackend`](super::FabricBackend)), in the style of the htsim
//! family of simulators: a single event heap over integer nanoseconds,
//! per-link FIFO queues with store-and-forward serialization, per-hop
//! propagation latency, and seeded round-robin endpoint injection.
//!
//! What it adds over the fluid engine — and the reason it exists — is
//! **queueing**: cells wait behind other cells, so incast, head-of-line
//! blocking and tail latency are first-class observations
//! ([`PacketSim::tail`]) instead of being fluid-averaged away.
//!
//! ## Model
//!
//! * Each [`Flow`] is carved into `n = ceil(bytes / cell_bytes)`
//!   equal-size **cells** (so byte conservation is exact), issued at
//!   `issue_t` + the same setup latency the fluid engine charges.
//! * Each **source GPU** owns an injector: a serializer at the per-GPU
//!   injection cap that round-robins across its flows (seeded initial
//!   rotation). Per flow, injection is additionally *paced* at the
//!   flow's rate ceiling (size efficiency × bottleneck × relay ρ — the
//!   same [`FabricParams::flow_rate_cap_gbps`] the fluid solver caps
//!   rates with), and *windowed*: at most `buffer_bytes` may be in
//!   flight per flow (the §IV-C P2P staging-buffer credit); the window
//!   reopens on delivery (credit return).
//! * Each **link** serializes the head of its FIFO at
//!   `min(link capacity, flow ceiling)`, then forwards the cell after
//!   `latency_ns` of propagation. Inter-node hops additionally charge
//!   the per-node NIC aggregate (the Fig 6b 170 GB/s anchor) as a
//!   serial token budget, so four 45.1 GB/s rails cannot exceed it.
//! * Each **destination GPU** drains arrivals through a receive stage
//!   at the HBM-write cap — the incast bottleneck.
//!
//! Every arbitration is deterministic: the event heap is keyed by
//! `(time, insertion seq)` and ties never consult unordered state, so
//! identical seeds produce **byte-identical event traces**
//! (`prop_packet_identical_seeds_identical_traces` in
//! `tests/fabric_props.rs` holds this).
//!
//! Preemption ([`PacketSim::preempt`]) mirrors the fluid engine's
//! semantics: the flow freezes at the bytes *delivered* so far and the
//! caller re-issues the residual on new paths; cells still inside the
//! fabric are aborted at their next event (their traversed hops stay
//! charged to `link_bytes` — rerouting is not free).

use super::backend::TailStats;
use super::faults;
use super::fluid::{Flow, FlowResult, SimResult};
use super::FabricParams;
use crate::topology::Topology;
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

/// Trace record: `(time_ns, code, a, b)` with the `TRACE_*` codes.
pub type TraceEvent = (u64, u8, u32, u32);

/// Trace code: cell `(flow a, cell b)` finished injector serialization.
pub const TRACE_INJECT: u8 = 1;
/// Trace code: link `a` finished serializing a cell of flow `b`.
pub const TRACE_LINK_DONE: u8 = 2;
/// Trace code: cell `(flow a, cell b)` delivered end-to-end.
pub const TRACE_DELIVER: u8 = 3;

/// Discrete events. Heap order is `(time, seq)`; the derived `Ord` on
/// the payload exists only to satisfy the heap's type bounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// Injector of GPU `g` may be free — attempt the next injection.
    Inject(u32),
    /// Cell `(flow, idx)` arrives at a link's input queue.
    Enq(u32, u32, u32),
    /// Link may complete its in-service cell and/or start the next.
    LinkTick(u32),
    /// Cell `(flow, idx)` arrives at GPU `g`'s receive stage.
    RecvEnq(u32, u32, u32),
    /// Receive stage of GPU `g` may complete and/or start the next.
    RecvTick(u32),
}

/// Virtual time in integer nanoseconds (1 GB/s ≡ 1 byte/ns, so rate
/// arithmetic needs no unit constants).
fn ns_of(t_s: f64) -> u64 {
    if t_s <= 0.0 {
        0
    } else {
        (t_s * 1e9).round() as u64
    }
}

/// Serialization time of `bytes` at `gbps`, in whole nanoseconds
/// (ceiling, minimum 1 ns so zero-duration service loops are
/// impossible).
fn dur_ns(bytes: f64, gbps: f64) -> u64 {
    debug_assert!(gbps > 0.0, "non-positive rate");
    (bytes / gbps).ceil().max(1.0) as u64
}

/// The packet-level discrete-event simulator. Construct with the full
/// initial flow set; drive through the [`FabricBackend`](super::FabricBackend)
/// surface (`advance_to` / `take_window` / `preempt` / `add_flows`).
pub struct PacketSim<'a> {
    topo: &'a Topology,
    params: FabricParams,
    // ---- per-flow state (issue order, like the fluid engine) ----
    flows: Vec<Flow>,
    start_t: Vec<f64>,
    t0_ns: Vec<u64>,
    cell_size: Vec<f64>,
    n_cells: Vec<u32>,
    injected: Vec<u32>,
    delivered: Vec<u32>,
    delivered_bytes: Vec<f64>,
    inflight_bytes: Vec<f64>,
    next_inject_ns: Vec<u64>,
    alive: Vec<bool>,
    preempted: Vec<bool>,
    /// `u64::MAX` while the flow is in flight.
    finish_ns: Vec<u64>,
    flow_cap_gbps: Vec<f64>,
    window_cap: Vec<f64>,
    /// Hop-0 enqueue timestamps, FIFO per flow (cells of one flow
    /// deliver in order, so transit latency pairs up by popping).
    enq0_q: Vec<VecDeque<u64>>,
    unfinished: usize,
    // ---- per-source-GPU injectors ----
    flows_at: Vec<Vec<u32>>,
    rr: Vec<usize>,
    inj_busy_until: Vec<u64>,
    // ---- per-link queues + servers ----
    lq: Vec<VecDeque<(u32, u32)>>,
    lq_bytes: Vec<f64>,
    peak_lq_bytes: Vec<f64>,
    /// `(flow, cell idx, completion time)` of the cell in service.
    in_service: Vec<Option<(u32, u32, u64)>>,
    link_rate: Vec<f64>,
    /// Per-link capacity scale under faults (1 healthy, 0 dead: the
    /// queue freezes until a restore event re-kicks the server).
    /// Scaling by exactly 1.0 is a bit-exact no-op, so fault-free runs
    /// keep byte-identical traces.
    link_scale: Vec<f64>,
    /// Per-GPU injector-serializer scale under faults (stragglers).
    inject_scale: Vec<f64>,
    /// Node whose NIC-out (resp. NIC-in) aggregate a cell on this link
    /// charges; `u32::MAX` = none. On flat fabrics every non-NVLink
    /// link charges both endpoints' nodes (the old `is_net` rule); on
    /// tiered fabrics leaf uplinks charge the source node's out clock,
    /// leaf downlinks the destination node's in clock, and spine links
    /// neither (switch-to-switch hops cross no host NIC).
    charge_out_node: Vec<u32>,
    charge_in_node: Vec<u32>,
    // ---- per-node NIC-aggregate token clocks ----
    net_out_free: Vec<u64>,
    net_in_free: Vec<u64>,
    // ---- per-destination-GPU receive stages ----
    rq: Vec<VecDeque<(u32, u32)>>,
    rq_bytes: Vec<f64>,
    peak_rq_bytes: Vec<f64>,
    r_in_service: Vec<Option<(u32, u32, u64)>>,
    // ---- accounting ----
    link_bytes: Vec<f64>,
    window_bytes: Vec<f64>,
    sojourn_s: Vec<f64>,
    transit_s: Vec<f64>,
    per_pair: BTreeMap<(usize, usize), Vec<f64>>,
    per_tag: BTreeMap<u64, Vec<f64>>,
    // ---- event core ----
    heap: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    seq: u64,
    t_ns: u64,
    events: u64,
    trace_on: bool,
    trace: Vec<TraceEvent>,
}

impl<'a> PacketSim<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams, flows: &[Flow]) -> Self {
        let nl = topo.links.len();
        let ng = topo.num_gpus();
        let nn = topo.nodes;
        let mut sim = PacketSim {
            topo,
            flows: Vec::new(),
            start_t: Vec::new(),
            t0_ns: Vec::new(),
            cell_size: Vec::new(),
            n_cells: Vec::new(),
            injected: Vec::new(),
            delivered: Vec::new(),
            delivered_bytes: Vec::new(),
            inflight_bytes: Vec::new(),
            next_inject_ns: Vec::new(),
            alive: Vec::new(),
            preempted: Vec::new(),
            finish_ns: Vec::new(),
            flow_cap_gbps: Vec::new(),
            window_cap: Vec::new(),
            enq0_q: Vec::new(),
            unfinished: 0,
            flows_at: vec![Vec::new(); ng],
            rr: (0..ng)
                .map(|g| {
                    // seeded initial rotation, reduced modulo the live
                    // flow count at pick time
                    Rng::new(
                        params.packet.seed
                            ^ (g as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    )
                    .next_u64() as usize
                })
                .collect(),
            inj_busy_until: vec![0; ng],
            lq: vec![VecDeque::new(); nl],
            lq_bytes: vec![0.0; nl],
            peak_lq_bytes: vec![0.0; nl],
            in_service: vec![None; nl],
            link_rate: topo.links.iter().map(|l| l.cap_gbps).collect(),
            link_scale: vec![1.0; nl],
            inject_scale: vec![1.0; ng],
            charge_out_node: topo
                .links
                .iter()
                .map(|l| topo.nic_out_node(l).map_or(u32::MAX, |n| n as u32))
                .collect(),
            charge_in_node: topo
                .links
                .iter()
                .map(|l| topo.nic_in_node(l).map_or(u32::MAX, |n| n as u32))
                .collect(),
            net_out_free: vec![0; nn],
            net_in_free: vec![0; nn],
            rq: vec![VecDeque::new(); ng],
            rq_bytes: vec![0.0; ng],
            peak_rq_bytes: vec![0.0; ng],
            r_in_service: vec![None; ng],
            link_bytes: vec![0.0; nl],
            window_bytes: vec![0.0; nl],
            sojourn_s: Vec::new(),
            transit_s: Vec::new(),
            per_pair: BTreeMap::new(),
            per_tag: BTreeMap::new(),
            heap: BinaryHeap::new(),
            seq: 0,
            t_ns: 0,
            events: 0,
            trace_on: false,
            trace: Vec::new(),
            params,
        };
        sim.add_flows(flows);
        sim
    }

    /// Record the compact event trace (determinism property tests);
    /// off by default to bound memory.
    pub fn set_trace(&mut self, on: bool) {
        self.trace_on = on;
    }

    /// The recorded trace (empty unless [`PacketSim::set_trace`]).
    pub fn trace(&self) -> &[TraceEvent] {
        &self.trace
    }

    /// Events processed so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.t_ns as f64 * 1e-9
    }

    /// All flows delivered or preempted.
    pub fn is_done(&self) -> bool {
        self.unfinished == 0
    }

    /// Bytes flow `i` still has to deliver (0 once finished/preempted).
    pub fn residual_bytes(&self, i: usize) -> f64 {
        if self.finish_ns[i] == u64::MAX {
            (self.flows[i].bytes.max(1.0) - self.delivered_bytes[i]).max(0.0)
        } else {
            0.0
        }
    }

    /// Bytes flow `i` has delivered end-to-end so far.
    pub fn moved_bytes(&self, i: usize) -> f64 {
        self.delivered_bytes[i]
    }

    /// Whether flow `i` is still in flight.
    pub fn is_live(&self, i: usize) -> bool {
        self.finish_ns[i] == u64::MAX
    }

    /// The flow registered under index `i` (issue order).
    pub fn flow(&self, i: usize) -> &Flow {
        &self.flows[i]
    }

    /// Cells flow `i` was carved into (equal-size, `bytes / cells`).
    pub fn cells_of(&self, i: usize) -> u32 {
        self.n_cells[i]
    }

    /// Register additional flows; returns the first new index.
    pub fn add_flows(&mut self, flows: &[Flow]) -> usize {
        let first = self.flows.len();
        for f in flows {
            let i = self.flows.len();
            let start_s = f.issue_t + self.params.start_latency_s(&f.path, f.mode);
            let bytes = f.bytes.max(1.0);
            let n = (bytes / self.params.packet.cell_bytes.max(1.0)).ceil().max(1.0);
            let cell = bytes / n;
            let cap = (self.params.flow_rate_cap_gbps(self.topo, &f.path, f.bytes)
                * f.rate_factor)
                .max(1e-3);
            self.start_t.push(start_s);
            self.t0_ns.push(ns_of(start_s));
            self.cell_size.push(cell);
            self.n_cells.push(n as u32);
            self.injected.push(0);
            self.delivered.push(0);
            self.delivered_bytes.push(0.0);
            self.inflight_bytes.push(0.0);
            self.next_inject_ns.push(0);
            self.alive.push(true);
            self.preempted.push(false);
            self.finish_ns.push(u64::MAX);
            self.flow_cap_gbps.push(cap);
            self.window_cap.push(self.params.packet.buffer_bytes.max(cell));
            self.enq0_q.push(VecDeque::new());
            self.flows_at[f.path.src].push(i as u32);
            self.unfinished += 1;
            let wake = self.t0_ns[i].max(self.t_ns);
            self.schedule(wake, Ev::Inject(f.path.src as u32));
            self.flows.push(f.clone());
        }
        first
    }

    /// Preempt flow `i`: freeze it at the bytes delivered so far and
    /// return the residual for re-issue. Cells still inside the fabric
    /// are aborted at their next event.
    pub fn preempt(&mut self, i: usize) -> f64 {
        if self.finish_ns[i] != u64::MAX {
            return 0.0;
        }
        let residual = self.residual_bytes(i);
        self.alive[i] = false;
        self.preempted[i] = true;
        self.finish_ns[i] = self.t_ns;
        self.inflight_bytes[i] = 0.0;
        self.unfinished -= 1;
        residual
    }

    /// Apply a fault: a dead link freezes its queue (the in-service
    /// cell still completes — it was already on the wire — but nothing
    /// new enters service); degraded links and straggling injectors
    /// serialize slower from their next cell on; restore events
    /// re-kick frozen servers. Fault-free runs never call this, so
    /// their event traces stay byte-identical.
    pub fn apply_fault(&mut self, fault: &faults::Fault) {
        let t = self.t_ns;
        match *fault {
            faults::Fault::LinkDown { link } => self.link_scale[link] = 0.0,
            faults::Fault::LinkUp { link } => {
                self.link_scale[link] = 1.0;
                self.schedule(t, Ev::LinkTick(link as u32));
            }
            faults::Fault::RailDegraded { rail, factor } => {
                for l in faults::rail_links(self.topo, rail) {
                    let was_dead = self.link_scale[l] <= 0.0;
                    self.link_scale[l] = factor;
                    if was_dead {
                        self.schedule(t, Ev::LinkTick(l as u32));
                    }
                }
            }
            faults::Fault::StragglerNode { node, inject_factor } => {
                for local in 0..self.topo.gpus_per_node {
                    let g = self.topo.gpu(node, local);
                    self.inject_scale[g] = inject_factor;
                }
                for l in faults::node_out_links(self.topo, node) {
                    let was_dead = self.link_scale[l] <= 0.0;
                    self.link_scale[l] = inject_factor;
                    if was_dead {
                        self.schedule(t, Ev::LinkTick(l as u32));
                    }
                }
            }
        }
    }

    /// Per-link bytes serialized since the previous call; resets the
    /// window counters (the monitor's sampling surface).
    pub fn take_window(&mut self) -> Vec<f64> {
        std::mem::replace(&mut self.window_bytes, vec![0.0; self.link_bytes.len()])
    }

    /// Advance the event loop until `t_stop` (a replan epoch boundary)
    /// or until every flow completes, whichever comes first.
    pub fn advance_to(&mut self, t_stop: f64) {
        let stop_ns = if t_stop.is_finite() { ns_of(t_stop) } else { u64::MAX };
        while self.unfinished > 0 {
            let Some(&Reverse((t, _, _))) = self.heap.peek() else {
                assert!(
                    stop_ns != u64::MAX,
                    "stuck: packet simulation has live flows but no events"
                );
                break;
            };
            if t > stop_ns {
                break;
            }
            let Reverse((t, _, ev)) = self.heap.pop().expect("peeked");
            self.t_ns = t;
            self.events += 1;
            match ev {
                Ev::Inject(g) => self.injector_tick(g as usize, t),
                Ev::Enq(l, f, idx) => self.enqueue_link(l as usize, f as usize, idx, t),
                Ev::LinkTick(l) => self.link_tick(l as usize, t),
                Ev::RecvEnq(g, f, idx) => {
                    self.enqueue_recv(g as usize, f as usize, idx, t)
                }
                Ev::RecvTick(g) => self.recv_tick(g as usize, t),
            }
        }
        if stop_ns != u64::MAX && stop_ns > self.t_ns {
            self.t_ns = stop_ns;
        }
    }

    /// Run every remaining event (no epoch bound).
    pub fn run_to_completion(&mut self) {
        self.advance_to(f64::INFINITY);
    }

    /// Snapshot the outcome in the same shape as the fluid engine:
    /// preempted flows report the bytes they actually delivered, so a
    /// preempted original and its re-issued residuals sum to the
    /// payload without double counting.
    pub fn result(&self) -> SimResult {
        let flows: Vec<FlowResult> = (0..self.flows.len())
            .map(|i| FlowResult {
                start_t: self.start_t[i],
                finish_t: if self.finish_ns[i] == u64::MAX {
                    f64::NAN
                } else {
                    self.finish_ns[i] as f64 * 1e-9
                },
                bytes: if self.preempted[i] {
                    self.delivered_bytes[i]
                } else {
                    self.flows[i].bytes
                },
            })
            .collect();
        let makespan = flows
            .iter()
            .map(|f| f.finish_t)
            .filter(|t| !t.is_nan())
            .fold(0.0, f64::max);
        SimResult { flows, link_bytes: self.link_bytes.clone(), makespan }
    }

    /// The latency/queue-depth observations this backend exists for.
    pub fn tail(&self) -> TailStats {
        TailStats {
            sojourn_s: self.sojourn_s.clone(),
            transit_s: self.transit_s.clone(),
            per_pair_sojourn_s: self.per_pair.clone(),
            per_tag_sojourn_s: self.per_tag.clone(),
            peak_queue_bytes: self.peak_lq_bytes.clone(),
            peak_recv_queue_bytes: self.peak_rq_bytes.clone(),
            delivered_chunks: self.sojourn_s.len() as u64,
        }
    }

    // ---- internals ----

    fn schedule(&mut self, t: u64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse((t, self.seq, ev)));
    }

    fn push_trace(&mut self, t: u64, code: u8, a: u32, b: u32) {
        if self.trace_on {
            self.trace.push((t, code, a, b));
        }
    }

    /// Position of link `l` on flow `f`'s path (a link appears at most
    /// once on any candidate path).
    fn hop_pos(&self, f: usize, l: usize) -> usize {
        self.flows[f]
            .path
            .hops
            .iter()
            .position(|&h| h == l)
            .expect("cell on a link outside its flow's path")
    }

    /// Injector of GPU `g` attempts one injection at time `t`.
    fn injector_tick(&mut self, g: usize, t: u64) {
        if t < self.inj_busy_until[g] {
            return; // the completion tick will re-attempt
        }
        let len = self.flows_at[g].len();
        if len == 0 {
            return;
        }
        let mut chosen = None;
        let mut wake = u64::MAX;
        for k in 0..len {
            let pos = (self.rr[g] + k) % len;
            let f = self.flows_at[g][pos] as usize;
            if !self.alive[f] || self.injected[f] >= self.n_cells[f] {
                continue;
            }
            if self.inflight_bytes[f] + self.cell_size[f] > self.window_cap[f] + 1e-9 {
                continue; // window closed: the credit return wakes us
            }
            let ready = self.t0_ns[f].max(self.next_inject_ns[f]);
            if ready > t {
                wake = wake.min(ready);
                continue;
            }
            chosen = Some(pos);
            break;
        }
        let Some(pos) = chosen else {
            if wake != u64::MAX {
                self.schedule(wake, Ev::Inject(g as u32));
            }
            return;
        };
        let f = self.flows_at[g][pos] as usize;
        self.rr[g] = (pos + 1) % len;
        let cell = self.cell_size[f];
        let dur = dur_ns(cell, self.params.inject_cap_gbps * self.inject_scale[g]);
        self.inj_busy_until[g] = t + dur;
        // token-bucket pacing at the flow's rate ceiling: deadlines
        // advance by one period per cell with at most one period of
        // banked credit, so injector-arbitration jitter delays cells
        // without compounding into a rate loss
        let period = dur_ns(cell, self.flow_cap_gbps[f]);
        self.next_inject_ns[f] =
            self.next_inject_ns[f].max(t.saturating_sub(period)) + period;
        let idx = self.injected[f];
        self.injected[f] += 1;
        self.inflight_bytes[f] += cell;
        self.push_trace(t, TRACE_INJECT, f as u32, idx);
        let hop0 = self.flows[f].path.hops[0] as u32;
        self.schedule(t + dur, Ev::Enq(hop0, f as u32, idx));
        self.schedule(t + dur, Ev::Inject(g as u32));
    }

    /// Cell `(f, idx)` arrives at link `l`'s input queue.
    fn enqueue_link(&mut self, l: usize, f: usize, idx: u32, t: u64) {
        if !self.alive[f] {
            return; // aborted mid-flight by a preemption
        }
        if self.hop_pos(f, l) == 0 {
            self.enq0_q[f].push_back(t);
        }
        self.lq[l].push_back((f as u32, idx));
        self.lq_bytes[l] += self.cell_size[f];
        if self.lq_bytes[l] > self.peak_lq_bytes[l] {
            self.peak_lq_bytes[l] = self.lq_bytes[l];
        }
        if self.in_service[l].is_none() {
            self.link_tick(l, t);
        }
    }

    /// Link `l` completes its in-service cell (if due) and starts the
    /// next one it can.
    fn link_tick(&mut self, l: usize, t: u64) {
        if let Some((fu, idx, done)) = self.in_service[l] {
            if t < done {
                return; // stale tick; the completion tick is scheduled
            }
            self.in_service[l] = None;
            let f = fu as usize;
            let cell = self.cell_size[f];
            self.link_bytes[l] += cell;
            self.window_bytes[l] += cell;
            self.push_trace(t, TRACE_LINK_DONE, l as u32, fu);
            if self.alive[f] {
                let pos = self.hop_pos(f, l);
                let arr = t + self.params.packet.latency_ns;
                let hops = &self.flows[f].path.hops;
                if pos + 1 < hops.len() {
                    let next = hops[pos + 1] as u32;
                    self.schedule(arr, Ev::Enq(next, fu, idx));
                } else {
                    let dst = self.flows[f].path.dst as u32;
                    self.schedule(arr, Ev::RecvEnq(dst, fu, idx));
                }
            }
        }
        if self.link_scale[l] <= 0.0 {
            return; // dead link: queue frozen until a restore re-kicks
        }
        loop {
            let Some(&(fu, idx)) = self.lq[l].front() else { return };
            let f = fu as usize;
            if !self.alive[f] {
                self.lq[l].pop_front();
                self.lq_bytes[l] -= self.cell_size[f];
                continue;
            }
            let mut s = t;
            let co = self.charge_out_node[l];
            let ci = self.charge_in_node[l];
            if co != u32::MAX {
                s = s.max(self.net_out_free[co as usize]);
            }
            if ci != u32::MAX {
                s = s.max(self.net_in_free[ci as usize]);
            }
            if s > t {
                // NIC-aggregate tokens not yet available: retry then
                self.schedule(s, Ev::LinkTick(l as u32));
                return;
            }
            self.lq[l].pop_front();
            let cell = self.cell_size[f];
            self.lq_bytes[l] -= cell;
            let rate = (self.link_rate[l] * self.link_scale[l]).min(self.flow_cap_gbps[f]);
            let done = t + dur_ns(cell, rate);
            self.in_service[l] = Some((fu, idx, done));
            if co != u32::MAX || ci != u32::MAX {
                let agg = dur_ns(cell, self.params.node_net_cap_gbps);
                if co != u32::MAX {
                    let sn = co as usize;
                    self.net_out_free[sn] = self.net_out_free[sn].max(t) + agg;
                }
                if ci != u32::MAX {
                    let dn = ci as usize;
                    self.net_in_free[dn] = self.net_in_free[dn].max(t) + agg;
                }
            }
            self.schedule(done, Ev::LinkTick(l as u32));
            return;
        }
    }

    /// Cell `(f, idx)` arrives at GPU `g`'s receive stage.
    fn enqueue_recv(&mut self, g: usize, f: usize, idx: u32, t: u64) {
        if !self.alive[f] {
            return;
        }
        self.rq[g].push_back((f as u32, idx));
        self.rq_bytes[g] += self.cell_size[f];
        if self.rq_bytes[g] > self.peak_rq_bytes[g] {
            self.peak_rq_bytes[g] = self.rq_bytes[g];
        }
        if self.r_in_service[g].is_none() {
            self.recv_tick(g, t);
        }
    }

    /// Receive stage of GPU `g` completes a delivery (if due) and
    /// starts draining the next arrival.
    fn recv_tick(&mut self, g: usize, t: u64) {
        if let Some((fu, idx, done)) = self.r_in_service[g] {
            if t < done {
                return;
            }
            self.r_in_service[g] = None;
            let f = fu as usize;
            if self.alive[f] {
                let cell = self.cell_size[f];
                self.delivered[f] += 1;
                self.delivered_bytes[f] += cell;
                self.inflight_bytes[f] = (self.inflight_bytes[f] - cell).max(0.0);
                let enq0 = self.enq0_q[f].pop_front().unwrap_or(self.t0_ns[f]);
                let sojourn = t.saturating_sub(self.t0_ns[f]) as f64 * 1e-9;
                let transit = t.saturating_sub(enq0) as f64 * 1e-9;
                self.sojourn_s.push(sojourn);
                self.transit_s.push(transit);
                let pair = (self.flows[f].path.src, self.flows[f].path.dst);
                self.per_pair.entry(pair).or_default().push(sojourn);
                self.per_tag.entry(self.flows[f].tag).or_default().push(sojourn);
                self.push_trace(t, TRACE_DELIVER, fu, idx);
                // credit return: the source may inject again
                let src = self.flows[f].path.src;
                let wake = t.max(self.inj_busy_until[src]);
                self.schedule(wake, Ev::Inject(src as u32));
                if self.delivered[f] == self.n_cells[f] {
                    self.finish_ns[f] = t;
                    self.unfinished -= 1;
                }
            }
        }
        loop {
            let Some(&(fu, idx)) = self.rq[g].front() else { return };
            let f = fu as usize;
            self.rq[g].pop_front();
            self.rq_bytes[g] -= self.cell_size[f];
            if !self.alive[f] {
                continue;
            }
            let done = t + dur_ns(self.cell_size[f], self.params.recv_cap_gbps);
            self.r_in_service[g] = Some((fu, idx, done));
            self.schedule(done, Ev::RecvTick(g as u32));
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;

    const MB: f64 = 1024.0 * 1024.0;

    fn run(topo: &Topology, flows: &[Flow]) -> (SimResult, TailStats) {
        let mut sim = PacketSim::new(topo, FabricParams::default(), flows);
        sim.run_to_completion();
        (sim.result(), sim.tail())
    }

    /// Fig 6a anchor on the packet backend: a single direct NVLink
    /// flow saturates near 120 GB/s (agreement with the fluid engine
    /// is asserted tighter in `exp::xcheck`).
    #[test]
    fn direct_nvlink_saturates() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 1, false).remove(0);
        let (r, tail) = run(&t, &[Flow::new(p, 256.0 * MB)]);
        let bw = r.aggregate_gbps();
        assert!(bw > 112.0 && bw <= 120.0, "bw={bw}");
        assert_eq!(tail.delivered_chunks, 1024);
        // uncontended: transit stays near the serialization floor —
        // pacing keeps queues shallow
        let worst = tail.transit_s.iter().cloned().fold(0.0, f64::max);
        assert!(worst < 100e-6, "uncontended transit ballooned: {worst}");
    }

    /// Fig 6a 3-path anchor: the per-GPU injection cap emerges from
    /// the injector serializer (no max-min solver involved).
    #[test]
    fn three_path_injection_cap_emerges() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let big = 128.0 * MB;
        let flows: Vec<Flow> =
            cands.iter().take(3).map(|p| Flow::new(p.clone(), big)).collect();
        let (r, _) = run(&t, &flows);
        let agg = 3.0 * big / r.makespan / 1e9;
        assert!((agg - 278.2).abs() < 14.0, "3-path agg={agg}");
    }

    /// Fig 6b anchor: four rails are clamped by the per-node NIC
    /// aggregate (170), not 4 × 45.1 = 180.4.
    #[test]
    fn four_rails_clamped_by_node_cap() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, t.gpu(1, 0), true);
        let big = 64.0 * MB;
        let flows: Vec<Flow> =
            cands.iter().map(|p| Flow::new(p.clone(), big)).collect();
        let (r, _) = run(&t, &flows);
        let agg = 4.0 * big / r.makespan / 1e9;
        assert!((agg - 170.0).abs() < 9.0, "4-rail agg={agg}");
        // single rail for contrast
        let (r1, _) = run(&t, &[Flow::new(cands[0].clone(), big)]);
        let bw1 = r1.aggregate_gbps();
        assert!((bw1 - 45.1).abs() < 2.5, "1-rail bw={bw1}");
    }

    /// Two equal flows over one link share it and finish together-ish;
    /// contention shows up as queueing the tail stats can see.
    #[test]
    fn fair_share_and_queueing_under_contention() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 1, false).remove(0);
        let flows =
            vec![Flow::new(p.clone(), 64.0 * MB), Flow::new(p.clone(), 64.0 * MB)];
        let (r, tail) = run(&t, &flows);
        let skew = (r.flows[0].finish_t - r.flows[1].finish_t).abs();
        assert!(skew < 50e-6, "finish skew {skew}");
        let bw = r.aggregate_gbps();
        assert!(bw <= 120.0 + 1e-6 && bw > 108.0, "bw={bw}");
        // the shared link queued cells (the fluid model cannot see this)
        let peak = tail.peak_queue_bytes.iter().cloned().fold(0.0, f64::max);
        assert!(peak > 0.0, "contention produced no queueing");
        // sojourn includes source-side pacing; transit is within it
        for (s, tr) in tail.sojourn_s.iter().zip(&tail.transit_s) {
            assert!(tr <= s, "transit {tr} exceeds sojourn {s}");
        }
    }

    /// Byte conservation through every hop: each of a 2-hop path's
    /// links carries the full payload exactly once.
    #[test]
    fn per_hop_byte_conservation() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 1, true).remove(1); // 2-hop relay
        let bytes = 48.0 * MB;
        let (r, _) = run(&t, &[Flow::new(p, bytes)]);
        let total: f64 = r.link_bytes.iter().sum();
        assert!((total - 2.0 * bytes).abs() < 1.0, "total={total}");
    }

    /// Identical seeds ⇒ byte-identical event traces and results; the
    /// seed genuinely feeds arbitration (different seeds still conserve).
    #[test]
    fn seeded_determinism() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let flows = vec![
            Flow::new(cands[0].clone(), 16.0 * MB),
            Flow::new(cands[1].clone(), 8.0 * MB),
            Flow::new(cands[2].clone(), 8.0 * MB).at(0.0002),
        ];
        let drive = |seed: u64| {
            let mut params = FabricParams::default();
            params.packet.seed = seed;
            let mut sim = PacketSim::new(&t, params, &flows);
            sim.set_trace(true);
            sim.run_to_completion();
            (sim.trace().to_vec(), sim.result(), sim.events())
        };
        let (tr_a, r_a, ev_a) = drive(7);
        let (tr_b, r_b, ev_b) = drive(7);
        assert_eq!(tr_a, tr_b, "same seed, different trace");
        assert_eq!(ev_a, ev_b);
        assert_eq!(r_a.makespan.to_bits(), r_b.makespan.to_bits());
        assert_eq!(r_a.link_bytes, r_b.link_bytes);
        let (_, r_c, _) = drive(8);
        let sum = |r: &SimResult| r.flows.iter().map(|f| f.bytes).sum::<f64>();
        assert!((sum(&r_a) - sum(&r_c)).abs() < 1.0, "seed changed physics");
    }

    /// Mid-flight preempt + re-issue conserves the stream payload and
    /// the re-issued path actually delivers the residual.
    #[test]
    fn preempt_and_reissue_conserves_bytes() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let bytes = 64.0 * MB;
        let mut sim = PacketSim::new(
            &t,
            FabricParams::default(),
            &[Flow::new(cands[0].clone(), bytes)],
        );
        sim.advance_to(0.0003);
        assert!(!sim.is_done());
        let residual = sim.preempt(0);
        assert!(residual > 0.0 && residual < bytes, "residual={residual}");
        let moved = sim.moved_bytes(0);
        assert!((moved + residual - bytes).abs() < 1.0);
        sim.add_flows(&[Flow::new(cands[1].clone(), residual).at(sim.now())]);
        sim.run_to_completion();
        let r = sim.result();
        let delivered: f64 = r.flows.iter().map(|f| f.bytes).sum();
        assert!((delivered - bytes).abs() < 1.0, "delivered={delivered}");
        assert!(r.flows[1].finish_t > r.flows[0].finish_t);
    }

    /// Epoch-sliced advancement processes the identical event sequence:
    /// results are bit-identical to one uninterrupted run, and window
    /// samples partition the cumulative link bytes.
    #[test]
    fn epoch_slicing_is_bit_identical_and_windows_partition() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, t.gpu(1, 1), true);
        let flows = vec![
            Flow::new(cands[0].clone(), 24.0 * MB),
            Flow::new(cands[1].clone(), 12.0 * MB).at(0.0004),
        ];
        let mut whole = PacketSim::new(&t, FabricParams::default(), &flows);
        whole.run_to_completion();
        let rw = whole.result();

        let mut sliced = PacketSim::new(&t, FabricParams::default(), &flows);
        let mut summed = vec![0.0; t.links.len()];
        let mut epoch = 0.0002;
        while !sliced.is_done() {
            sliced.advance_to(epoch);
            for (s, w) in summed.iter_mut().zip(sliced.take_window()) {
                *s += w;
            }
            epoch += 0.0002;
        }
        let rs = sliced.result();
        assert_eq!(rw.makespan.to_bits(), rs.makespan.to_bits());
        for (a, b) in rw.flows.iter().zip(&rs.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
        }
        assert_eq!(rw.link_bytes, rs.link_bytes);
        for (i, (&s, &tot)) in summed.iter().zip(&rs.link_bytes).enumerate() {
            assert!((s - tot).abs() < 1.0, "link {i}: windows {s} vs total {tot}");
        }
    }

    /// A dead link freezes delivery (in-flight cells drain, then
    /// nothing moves) until LinkUp re-kicks the server; the payload
    /// still lands in full.
    #[test]
    fn fault_flap_freezes_then_recovers() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0); // rail 0, single hop
        let link = p.hops[0];
        let bytes = 64.0 * MB;
        let mut sim =
            PacketSim::new(&t, FabricParams::default(), &[Flow::new(p, bytes)]);
        sim.advance_to(0.0003);
        sim.apply_fault(&faults::Fault::LinkDown { link });
        sim.advance_to(0.0050);
        assert!(!sim.is_done(), "flow finished across a dead link");
        let stalled = sim.moved_bytes(0);
        sim.advance_to(0.0060);
        assert!(
            (sim.moved_bytes(0) - stalled).abs() < 1.0,
            "dead link kept delivering"
        );
        sim.apply_fault(&faults::Fault::LinkUp { link });
        sim.run_to_completion();
        assert!(sim.is_done());
        let r = sim.result();
        assert!((r.flows[0].bytes - bytes).abs() < 1.0);
    }

    /// A degraded rail serializes slower: same payload, a multiple of
    /// the healthy makespan, bytes conserved.
    #[test]
    fn fault_degrade_slows_rail() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0);
        let bytes = 32.0 * MB;
        let fly = |fault: Option<faults::Fault>| {
            let mut sim = PacketSim::new(
                &t,
                FabricParams::default(),
                &[Flow::new(p.clone(), bytes)],
            );
            if let Some(f) = fault {
                sim.apply_fault(&f);
            }
            sim.run_to_completion();
            sim.result().makespan
        };
        let healthy = fly(None);
        let degraded =
            fly(Some(faults::Fault::RailDegraded { rail: 0, factor: 0.25 }));
        assert!(
            degraded > 2.0 * healthy,
            "degrade had no effect: {degraded} vs {healthy}"
        );
    }

    /// Incast: 7 senders into one destination queue up at the receive
    /// stage; the tail observes it (p99 transit ≫ uncontended) while
    /// goodput stays near the receive cap.
    #[test]
    fn incast_shows_up_in_tail_latency() {
        let t = Topology::paper();
        let dst = 1usize;
        let flows: Vec<Flow> = (0..t.num_gpus())
            .filter(|&s| s != dst)
            .map(|s| Flow::new(candidates(&t, s, dst, false).remove(0), 16.0 * MB))
            .collect();
        let (r, tail) = run(&t, &flows);
        let payload = 7.0 * 16.0 * MB;
        let agg = payload / r.makespan / 1e9;
        assert!(agg < 278.2 + 1.0, "incast beat the receive cap: {agg}");
        let p99 = crate::util::stats::p99(&tail.transit_s);
        let p50 = crate::util::stats::p50(&tail.transit_s);
        assert!(p99 >= p50, "percentiles out of order");
        let peak_rq = tail.peak_recv_queue_bytes[dst];
        assert!(peak_rq > 0.0, "incast produced no receive-side queueing");
    }
}
