//! Flow-level fluid simulator with progressive filling.
//!
//! Flows are (path, bytes, start-time, transfer-mode) tuples. Between
//! events (flow arrival / completion) every active flow transfers at a
//! constant rate given by **max-min fair sharing** over the fabric's
//! capacity constraints:
//!
//! * per-link capacity (NVLink edge, NIC rail, cross-rail),
//! * per-GPU injection cap (HBM read + copy kernels at the source),
//! * per-GPU receive cap (HBM write at the destination),
//! * per-node aggregate NIC cap (in each direction),
//! * per-flow rate ceiling (size efficiency × bottleneck × relay ρ).
//!
//! The water-filling computation raises all unfrozen flows' rates
//! uniformly until some constraint saturates, freezes that
//! constraint's flows, and repeats — the textbook max-min allocation
//! generalized to multiple resource kinds.
//!
//! Two execution surfaces share the same event loop:
//!
//! * [`FluidSim::run`] — run a fixed flow set to completion (the
//!   closed-form path used by every static experiment);
//! * [`SimEngine`] — the resumable form behind the execution-time
//!   re-planning loop (paper §I/§IV "execution-time planning"):
//!   advance virtual time in bounded steps, sample per-link byte
//!   windows for the monitor, **preempt** a flow's residual bytes and
//!   re-issue them on different paths at a replan epoch.

use super::{gbps_to_bps, FabricParams, XferMode};
use crate::topology::{LinkKind, Path, Topology};

/// One transfer request routed over a fixed path.
#[derive(Clone, Debug)]
pub struct Flow {
    pub path: Path,
    pub bytes: f64,
    /// Virtual time (seconds) when the flow is issued.
    pub issue_t: f64,
    pub mode: XferMode,
    /// Extra per-flow rate derating (e.g. non-affine GPU↔HCA access
    /// over the PCIe host bridge in the UCX baseline). 1.0 = none.
    pub rate_factor: f64,
}

impl Flow {
    pub fn new(path: Path, bytes: f64) -> Flow {
        Flow { path, bytes, issue_t: 0.0, mode: XferMode::Kernel, rate_factor: 1.0 }
    }
    pub fn with_rate_factor(mut self, f: f64) -> Flow {
        self.rate_factor = f;
        self
    }
    pub fn at(mut self, t: f64) -> Flow {
        self.issue_t = t;
        self
    }
    pub fn with_mode(mut self, m: XferMode) -> Flow {
        self.mode = m;
        self
    }
}

/// Per-flow outcome.
#[derive(Clone, Debug)]
pub struct FlowResult {
    /// When the flow's pipeline began moving data (issue + setup).
    pub start_t: f64,
    /// When the last byte landed.
    pub finish_t: f64,
    pub bytes: f64,
}

impl FlowResult {
    /// Achieved end-to-end bandwidth in GB/s (counted from issue).
    pub fn gbps_from(&self, issue_t: f64) -> f64 {
        self.bytes / (self.finish_t - issue_t) / 1e9
    }
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub flows: Vec<FlowResult>,
    /// Total bytes carried per link over the run.
    pub link_bytes: Vec<f64>,
    /// Time the last flow finished.
    pub makespan: f64,
}

impl SimResult {
    /// Per-link utilization (fraction of capacity × makespan used),
    /// restricted to links that carried any traffic.
    pub fn link_utilization(&self, topo: &Topology) -> Vec<(usize, f64)> {
        self.link_bytes
            .iter()
            .enumerate()
            .filter(|(_, &b)| b > 0.0)
            .map(|(i, &b)| {
                let cap = gbps_to_bps(topo.link(i).cap_gbps);
                (i, b / (cap * self.makespan.max(1e-12)))
            })
            .collect()
    }

    /// Aggregate achieved bandwidth (GB/s) across a set of flows that
    /// all started at t=0: total bytes / makespan.
    pub fn aggregate_gbps(&self) -> f64 {
        let total: f64 = self.flows.iter().map(|f| f.bytes).sum();
        total / self.makespan.max(1e-12) / 1e9
    }
}

/// Internal: one capacity constraint (bytes/s) over a set of flows.
struct Constraint {
    cap: f64,
    members: Vec<usize>,
}

/// The fluid fabric simulator.
pub struct FluidSim<'a> {
    pub topo: &'a Topology,
    pub params: FabricParams,
}

impl<'a> FluidSim<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams) -> Self {
        FluidSim { topo, params }
    }

    /// Run all flows to completion; returns per-flow finish times and
    /// per-link byte totals. Implemented on [`SimEngine`] so the static
    /// path and the resumable re-planning path share one event loop.
    pub fn run(&self, flows: &[Flow]) -> SimResult {
        let mut engine = SimEngine::new(self.topo, self.params.clone(), flows);
        engine.run_to_completion();
        engine.result()
    }

    /// Assemble every capacity constraint touching any flow.
    fn build_constraints(&self, flows: &[Flow]) -> Vec<Constraint> {
        let p = &self.params;
        let mut out = Vec::new();
        // per-link
        let mut link_members: Vec<Vec<usize>> = vec![Vec::new(); self.topo.links.len()];
        // per-GPU inject/recv
        let g = self.topo.num_gpus();
        let mut inj: Vec<Vec<usize>> = vec![Vec::new(); g];
        let mut rcv: Vec<Vec<usize>> = vec![Vec::new(); g];
        // per-node net out/in
        let nn = self.topo.nodes;
        let mut net_out: Vec<Vec<usize>> = vec![Vec::new(); nn];
        let mut net_in: Vec<Vec<usize>> = vec![Vec::new(); nn];

        for (i, f) in flows.iter().enumerate() {
            for &h in &f.path.hops {
                link_members[h].push(i);
                let l = self.topo.link(h);
                if !matches!(l.kind, LinkKind::NvLink) {
                    net_out[self.topo.node_of(l.src)].push(i);
                    net_in[self.topo.node_of(l.dst)].push(i);
                }
            }
            inj[f.path.src].push(i);
            rcv[f.path.dst].push(i);
        }
        for (id, members) in link_members.into_iter().enumerate() {
            if !members.is_empty() {
                out.push(Constraint {
                    cap: gbps_to_bps(self.topo.link(id).cap_gbps),
                    members,
                });
            }
        }
        for members in inj {
            if members.len() > 1 {
                out.push(Constraint { cap: gbps_to_bps(p.inject_cap_gbps), members });
            }
        }
        for members in rcv {
            if members.len() > 1 {
                out.push(Constraint { cap: gbps_to_bps(p.recv_cap_gbps), members });
            }
        }
        for members in net_out.into_iter().chain(net_in) {
            if members.len() > 1 {
                out.push(Constraint { cap: gbps_to_bps(p.node_net_cap_gbps), members });
            }
        }
        out
    }

    /// Water-filling max-min fair rates for the active flow set.
    /// `flow_cons[i]` lists the constraints flow `i` belongs to.
    fn max_min_rates(
        &self,
        constraints: &[Constraint],
        flow_cons: &[Vec<usize>],
        rate_cap: &[f64],
        active: &[usize],
        rates: &mut [f64],
    ) {
        for r in rates.iter_mut() {
            *r = 0.0;
        }
        let mut frozen: Vec<bool> = vec![true; rates.len()];
        for &i in active {
            frozen[i] = false;
        }
        // residual capacity + live member count per constraint
        let mut residual: Vec<f64> = constraints.iter().map(|c| c.cap).collect();
        let mut live: Vec<usize> = constraints
            .iter()
            .map(|c| c.members.iter().filter(|&&m| !frozen[m]).count())
            .collect();
        let mut level = 0.0f64; // common rate level of unfrozen flows
        let mut n_unfrozen = active.len();
        while n_unfrozen > 0 {
            // headroom per constraint: residual / live members
            let mut delta = f64::INFINITY;
            for ci in 0..constraints.len() {
                if live[ci] > 0 {
                    delta = delta.min(residual[ci] / live[ci] as f64);
                }
            }
            // per-flow ceilings
            for (i, &f) in frozen.iter().enumerate() {
                if !f {
                    delta = delta.min(rate_cap[i] - level);
                }
            }
            if !delta.is_finite() {
                // no binding constraint: everyone rides their own cap
                delta = 0.0;
            }
            let delta = delta.max(0.0);
            level += delta;
            // charge constraints
            for ci in 0..constraints.len() {
                if live[ci] > 0 {
                    residual[ci] -= delta * live[ci] as f64;
                }
            }
            // freeze: flows at their cap, or in a saturated constraint
            let mut newly_frozen = Vec::new();
            for &i in active {
                if !frozen[i] && rate_cap[i] - level <= 1e-9 {
                    newly_frozen.push(i);
                }
            }
            for (ci, c) in constraints.iter().enumerate() {
                if live[ci] > 0 && residual[ci] <= 1e-9 {
                    for &m in &c.members {
                        if !frozen[m] {
                            newly_frozen.push(m);
                        }
                    }
                }
            }
            if newly_frozen.is_empty() {
                // numerical corner: freeze everything at current level
                for &i in active {
                    if !frozen[i] {
                        rates[i] = level;
                        frozen[i] = true;
                    }
                }
                break;
            }
            newly_frozen.sort_unstable();
            newly_frozen.dedup();
            for i in newly_frozen {
                if frozen[i] {
                    continue;
                }
                rates[i] = level;
                frozen[i] = true;
                n_unfrozen -= 1;
                for &ci in &flow_cons[i] {
                    if live[ci] > 0 {
                        live[ci] -= 1;
                    }
                }
            }
        }
    }
}

/// Resumable fluid-simulation engine: the mechanism under the
/// execution-time re-planning loop.
///
/// The engine owns the event loop of [`FluidSim::run`] but exposes it
/// incrementally:
///
/// * [`SimEngine::advance_to`] runs events up to a virtual-time bound
///   (a replan epoch boundary);
/// * [`SimEngine::take_window`] drains the per-link byte counters
///   accumulated since the previous call (the monitor's sampling
///   window);
/// * [`SimEngine::preempt`] stops a flow mid-transfer and returns its
///   residual bytes so the coordinator can re-issue them on new paths
///   via [`SimEngine::add_flows`].
///
/// With no preemptions or additions, `run_to_completion` reproduces
/// [`FluidSim::run`] event-for-event (bit-identical results) — the
/// guarantee behind "replanning disabled ⇒ byte-identical to the
/// static path".
pub struct SimEngine<'a> {
    sim: FluidSim<'a>,
    flows: Vec<Flow>,
    start_t: Vec<f64>,
    remaining: Vec<f64>,
    moved: Vec<f64>,
    finish_t: Vec<f64>,
    link_bytes: Vec<f64>,
    window_bytes: Vec<f64>,
    t: f64,
    active: Vec<usize>,
    /// Sorted by start time, descending (pop from the back = earliest).
    pending: Vec<usize>,
    constraints: Vec<Constraint>,
    flow_cons: Vec<Vec<usize>>,
    rate_cap: Vec<f64>,
    rates: Vec<f64>,
    /// Flows preempted before completing (residual re-issued elsewhere).
    preempted: Vec<bool>,
}

impl<'a> SimEngine<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams, flows: &[Flow]) -> Self {
        let mut e = SimEngine {
            sim: FluidSim { topo, params },
            flows: Vec::new(),
            start_t: Vec::new(),
            remaining: Vec::new(),
            moved: Vec::new(),
            finish_t: Vec::new(),
            link_bytes: vec![0.0; topo.links.len()],
            window_bytes: vec![0.0; topo.links.len()],
            t: 0.0,
            active: Vec::new(),
            pending: Vec::new(),
            constraints: Vec::new(),
            flow_cons: Vec::new(),
            rate_cap: Vec::new(),
            rates: Vec::new(),
            preempted: Vec::new(),
        };
        e.add_flows(flows);
        e
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.t
    }

    /// All flows delivered or preempted.
    pub fn is_done(&self) -> bool {
        self.active.is_empty() && self.pending.is_empty()
    }

    /// Bytes flow `i` still has to deliver (0 once finished/preempted).
    pub fn residual_bytes(&self, i: usize) -> f64 {
        if self.finish_t[i].is_nan() {
            self.remaining[i].max(0.0)
        } else {
            0.0
        }
    }

    /// Bytes flow `i` has actually moved so far.
    pub fn moved_bytes(&self, i: usize) -> f64 {
        self.moved[i]
    }

    /// Whether flow `i` is still in flight (issued or queued).
    pub fn is_live(&self, i: usize) -> bool {
        self.finish_t[i].is_nan()
    }

    /// The flow registered under index `i` (issue order).
    pub fn flow(&self, i: usize) -> &Flow {
        &self.flows[i]
    }

    /// Register additional flows (re-issued residuals at a replan
    /// epoch). Their `issue_t` should not precede [`SimEngine::now`].
    /// Returns the index of the first newly added flow.
    pub fn add_flows(&mut self, flows: &[Flow]) -> usize {
        let first = self.flows.len();
        for f in flows {
            let i = self.flows.len();
            self.start_t
                .push(f.issue_t + self.sim.params.start_latency_s(&f.path, f.mode));
            self.remaining.push(f.bytes.max(1.0));
            self.moved.push(0.0);
            self.finish_t.push(f64::NAN);
            self.preempted.push(false);
            self.flows.push(f.clone());
            self.pending.push(i);
        }
        // Rebuild the constraint structure over the full flow set (the
        // solver only ever raises rates of *active* members, so closed
        // flows in a membership list are inert).
        self.constraints = self.sim.build_constraints(&self.flows);
        self.flow_cons = vec![Vec::new(); self.flows.len()];
        for (ci, c) in self.constraints.iter().enumerate() {
            for &m in &c.members {
                self.flow_cons[m].push(ci);
            }
        }
        self.rate_cap = self
            .flows
            .iter()
            .map(|f| {
                gbps_to_bps(self.sim.params.flow_rate_cap_gbps(
                    self.sim.topo,
                    &f.path,
                    f.bytes,
                )) * f.rate_factor
            })
            .collect();
        self.rates = vec![0.0; self.flows.len()];
        let start_t = &self.start_t;
        self.pending
            .sort_by(|&a, &b| start_t[a].partial_cmp(&start_t[b]).unwrap());
        self.pending.reverse(); // pop from the back = earliest
        first
    }

    /// Preempt flow `i`: freeze it at the bytes moved so far and return
    /// the residual byte count for re-issue on other paths. Finished
    /// flows return 0. The flow's result records its preemption time as
    /// `finish_t` and only the bytes it actually carried.
    pub fn preempt(&mut self, i: usize) -> f64 {
        if !self.finish_t[i].is_nan() {
            return 0.0;
        }
        let residual = self.remaining[i].max(0.0);
        if let Some(pos) = self.active.iter().position(|&x| x == i) {
            self.active.swap_remove(pos);
        } else if let Some(pos) = self.pending.iter().position(|&x| x == i) {
            self.pending.remove(pos);
        }
        self.finish_t[i] = self.t;
        self.remaining[i] = 0.0;
        self.preempted[i] = true;
        residual
    }

    /// Per-link bytes moved since the previous `take_window` call (the
    /// monitor's sampling window); resets the window counters.
    pub fn take_window(&mut self) -> Vec<f64> {
        std::mem::replace(&mut self.window_bytes, vec![0.0; self.link_bytes.len()])
    }

    /// Advance the event loop until `t_stop` (a replan epoch boundary)
    /// or until every flow completes, whichever comes first.
    pub fn advance_to(&mut self, t_stop: f64) {
        while !self.is_done() {
            // admit arrivals at the current time
            while let Some(&i) = self.pending.last() {
                if self.start_t[i] <= self.t + 1e-15 {
                    self.active.push(i);
                    self.pending.pop();
                } else {
                    break;
                }
            }
            if self.active.is_empty() {
                let next = self.start_t[*self.pending.last().unwrap()];
                if next > t_stop {
                    self.t = self.t.max(t_stop);
                    return;
                }
                self.t = next;
                continue;
            }
            self.sim.max_min_rates(
                &self.constraints,
                &self.flow_cons,
                &self.rate_cap,
                &self.active,
                &mut self.rates,
            );
            // next event: earliest completion or next arrival
            let mut dt = f64::INFINITY;
            for &i in &self.active {
                if self.rates[i] > 0.0 {
                    dt = dt.min(self.remaining[i] / self.rates[i]);
                }
            }
            if let Some(&i) = self.pending.last() {
                dt = dt.min(self.start_t[i] - self.t);
            }
            assert!(dt.is_finite(), "stuck: no progress possible (all rates zero)");
            // clamp at the epoch boundary
            let stopping = self.t + dt > t_stop;
            let dt = if stopping { (t_stop - self.t).max(0.0) } else { dt };
            // advance
            for &i in &self.active {
                let moved = self.rates[i] * dt;
                self.remaining[i] -= moved;
                self.moved[i] += moved;
                for &h in &self.flows[i].path.hops {
                    self.link_bytes[h] += moved;
                    self.window_bytes[h] += moved;
                }
            }
            self.t += dt;
            // retire completions
            let t = self.t;
            let remaining = &self.remaining;
            let finish_t = &mut self.finish_t;
            self.active.retain(|&i| {
                if remaining[i] <= 1e-6 {
                    finish_t[i] = t;
                    false
                } else {
                    true
                }
            });
            if stopping {
                return;
            }
        }
        if t_stop.is_finite() && t_stop > self.t {
            self.t = t_stop;
        }
    }

    /// Run every remaining event (no epoch bound).
    pub fn run_to_completion(&mut self) {
        self.advance_to(f64::INFINITY);
    }

    /// Snapshot the outcome. `FlowResult::bytes` is the bytes a flow
    /// actually carried, so preempted flows and their re-issued
    /// residuals sum to the original payload without double counting.
    pub fn result(&self) -> SimResult {
        let makespan = self
            .finish_t
            .iter()
            .cloned()
            .filter(|t| !t.is_nan())
            .fold(0.0, f64::max);
        SimResult {
            flows: (0..self.flows.len())
                .map(|i| FlowResult {
                    start_t: self.start_t[i],
                    finish_t: self.finish_t[i],
                    bytes: if self.preempted[i] {
                        self.moved[i]
                    } else {
                        self.flows[i].bytes
                    },
                })
                .collect(),
            link_bytes: self.link_bytes.clone(),
            makespan,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;
    use crate::topology::Topology;

    const MB: f64 = 1024.0 * 1024.0;

    fn sim(topo: &Topology) -> FluidSim<'_> {
        FluidSim::new(topo, FabricParams::default())
    }

    /// Fig 6a anchor: direct NVLink large-message ≈ 120 GB/s.
    #[test]
    fn direct_nvlink_saturates() {
        let t = Topology::paper();
        let s = sim(&t);
        let path = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[Flow::new(path, 1024.0 * MB)]);
        let bw = r.aggregate_gbps();
        assert!(bw > 115.0 && bw <= 120.0, "bw={bw}");
    }

    /// Fig 6a anchor: direct + 1 relay ⇒ ≈213 GB/s; +2 relays ⇒ ≈278 GB/s.
    #[test]
    fn multipath_intra_matches_paper_anchors() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 1, true);
        let big = 512.0 * MB;
        // direct + via-2, bytes split ∝ the achievable per-path rates
        // (120 : ρ·120) so the two flows drain together.
        let r2 = s.run(&[
            Flow::new(cands[0].clone(), big),
            Flow::new(cands[1].clone(), big * 0.776),
        ]);
        let bw2 = (big + big * 0.776) / r2.makespan / 1e9;
        assert!((bw2 - 213.1).abs() < 8.0, "2-path bw={bw2}");
        // direct + via-2 + via-3: the source injection cap (278.2)
        // binds and max-min equalizes the three flows near 92.7 GB/s
        // each, so an equal byte split drains together. The AGGREGATE
        // is the paper's 278.2 anchor.
        let r3 = s.run(&[
            Flow::new(cands[0].clone(), big),
            Flow::new(cands[1].clone(), big),
            Flow::new(cands[2].clone(), big),
        ]);
        let bw3 = (3.0 * big) / r3.makespan / 1e9;
        assert!((bw3 - 278.2).abs() < 10.0, "3-path bw={bw3}");
    }

    /// Fig 6b anchor: 1 rail ≈45.1, 4 rails ≈170 GB/s aggregate.
    #[test]
    fn multirail_inter_matches_paper_anchors() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 4, true); // gpu0 → gpu4 (node1, rail0)
        let big = 512.0 * MB;
        let r1 = s.run(&[Flow::new(cands[0].clone(), big)]);
        let bw1 = r1.aggregate_gbps();
        assert!((bw1 - 45.1).abs() < 2.0, "1 rail bw={bw1}");
        let flows: Vec<Flow> =
            cands.iter().map(|p| Flow::new(p.clone(), big)).collect();
        let r4 = s.run(&flows);
        let bw4 = (4.0 * big) / r4.makespan / 1e9;
        assert!((bw4 - 170.0).abs() < 6.0, "4 rails bw={bw4}");
    }

    #[test]
    fn link_byte_conservation() {
        let t = Topology::paper();
        let s = sim(&t);
        let path = candidates(&t, 0, 1, true).remove(1); // 2-hop
        let bytes = 64.0 * MB;
        let r = s.run(&[Flow::new(path, bytes)]);
        // each of the 2 hops carries the full payload
        let total: f64 = r.link_bytes.iter().sum();
        assert!((total - 2.0 * bytes).abs() < 1.0, "total={total}");
    }

    #[test]
    fn fair_share_two_flows_one_link() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        // two equal flows over the same NVLink edge finish together at
        // half rate each.
        let r = s.run(&[
            Flow::new(p.clone(), 256.0 * MB),
            Flow::new(p.clone(), 256.0 * MB),
        ]);
        let d = (r.flows[0].finish_t - r.flows[1].finish_t).abs();
        assert!(d < 1e-6, "finish skew {d}");
        let bw = r.aggregate_gbps();
        assert!(bw <= 120.0 + 1e-6 && bw > 110.0, "bw={bw}");
    }

    #[test]
    fn staggered_arrivals_progress() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[
            Flow::new(p.clone(), 64.0 * MB),
            Flow::new(p.clone(), 64.0 * MB).at(0.01),
        ]);
        assert!(r.flows[1].finish_t > r.flows[0].finish_t);
        assert!(r.makespan >= 0.01);
    }

    #[test]
    fn small_message_latency_dominated() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[Flow::new(p, 64.0 * 1024.0)]); // 64 KB
        let bw = r.aggregate_gbps();
        // far from peak: overhead + unsaturated curve
        assert!(bw < 10.0, "bw={bw}");
    }

    /// With no preemptions/additions, engine == closed-form run,
    /// bit for bit (run() IS the engine, but guard the equivalence).
    #[test]
    fn engine_matches_run_bit_identically() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 1, true);
        let flows = vec![
            Flow::new(cands[0].clone(), 96.0 * MB),
            Flow::new(cands[1].clone(), 64.0 * MB).at(0.0005),
            Flow::new(cands[2].clone(), 32.0 * MB).at(0.001),
        ];
        let r1 = s.run(&flows);
        let mut e = SimEngine::new(&t, FabricParams::default(), &flows);
        e.run_to_completion();
        let r2 = e.result();
        assert_eq!(r1.makespan.to_bits(), r2.makespan.to_bits());
        for (a, b) in r1.flows.iter().zip(&r2.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
        }
        assert_eq!(r1.link_bytes, r2.link_bytes);
    }

    /// Epoch-sliced advancement only splits integration intervals; the
    /// trajectory stays the same up to float noise.
    #[test]
    fn engine_epoch_slicing_preserves_trajectory() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let flows = vec![
            Flow::new(p.clone(), 256.0 * MB),
            Flow::new(p.clone(), 128.0 * MB).at(0.002),
        ];
        let whole = s.run(&flows);
        let mut e = SimEngine::new(&t, FabricParams::default(), &flows);
        let mut epoch = 0.0005;
        while !e.is_done() {
            e.advance_to(epoch);
            epoch += 0.0005;
        }
        let sliced = e.result();
        assert!((whole.makespan - sliced.makespan).abs() < 1e-9);
        for (a, b) in whole.link_bytes.iter().zip(&sliced.link_bytes) {
            assert!((a - b).abs() < 1.0, "link bytes drifted: {a} vs {b}");
        }
    }

    /// Preempting a flow and re-issuing its residual on another path
    /// conserves payload bytes and still finishes everything.
    #[test]
    fn engine_preempt_and_reissue_conserves_bytes() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let bytes = 256.0 * MB;
        let initial = [Flow::new(cands[0].clone(), bytes)];
        let mut e = SimEngine::new(&t, FabricParams::default(), &initial);
        // run a third of the nominal drain, then reroute the rest
        e.advance_to(0.0008);
        assert!(!e.is_done());
        let residual = e.preempt(0);
        assert!(residual > 0.0 && residual < bytes);
        let moved = e.moved_bytes(0);
        assert!((moved + residual - bytes).abs() < 1.0);
        e.add_flows(&[Flow::new(cands[1].clone(), residual).at(e.now())]);
        e.run_to_completion();
        let r = e.result();
        let delivered: f64 = r.flows.iter().map(|f| f.bytes).sum();
        assert!((delivered - bytes).abs() < 1.0, "delivered {delivered}");
        assert!(r.flows[1].finish_t > r.flows[0].finish_t);
        // the relay path actually carried the residual
        for &h in &cands[1].hops {
            assert!((r.link_bytes[h] - residual).abs() < 1.0);
        }
    }

    /// Window sampling partitions the cumulative per-link byte counts.
    #[test]
    fn engine_windows_partition_link_bytes() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0);
        let mut e =
            SimEngine::new(&t, FabricParams::default(), &[Flow::new(p, 64.0 * MB)]);
        let mut summed = vec![0.0; t.links.len()];
        let mut epoch = 0.0004;
        while !e.is_done() {
            e.advance_to(epoch);
            for (s, w) in summed.iter_mut().zip(e.take_window()) {
                *s += w;
            }
            epoch += 0.0004;
        }
        let r = e.result();
        for (i, (&s, &tot)) in summed.iter().zip(&r.link_bytes).enumerate() {
            assert!((s - tot).abs() < 1.0, "link {i}: windows {s} vs total {tot}");
        }
    }

    #[test]
    fn node_net_cap_binds_aggregate() {
        let t = Topology::paper();
        let s = sim(&t);
        // all 4 GPUs of node 0 send to their rail peers simultaneously
        let mut flows = Vec::new();
        for g in 0..4 {
            let p = candidates(&t, g, g + 4, false).remove(0);
            flows.push(Flow::new(p, 512.0 * MB));
        }
        let r = s.run(&flows);
        let agg = (4.0 * 512.0 * MB) / r.makespan / 1e9;
        assert!(agg <= 170.0 + 1.0, "agg={agg}");
        assert!(agg > 160.0, "agg={agg}");
    }
}
