//! Flow-level fluid simulator with progressive filling.
//!
//! Flows are (path, bytes, start-time, transfer-mode) tuples. Between
//! events (flow arrival / completion) every active flow transfers at a
//! constant rate given by **max-min fair sharing** over the fabric's
//! capacity constraints:
//!
//! * per-link capacity (NVLink edge, NIC rail, cross-rail),
//! * per-GPU injection cap (HBM read + copy kernels at the source),
//! * per-GPU receive cap (HBM write at the destination),
//! * per-node aggregate NIC cap (in each direction),
//! * per-flow rate ceiling (size efficiency × bottleneck × relay ρ).
//!
//! The water-filling computation raises all unfrozen flows' rates
//! uniformly until some constraint saturates, freezes that
//! constraint's flows, and repeats — the textbook max-min allocation
//! generalized to multiple resource kinds.

use super::{gbps_to_bps, FabricParams, XferMode};
use crate::topology::{LinkKind, Path, Topology};

/// One transfer request routed over a fixed path.
#[derive(Clone, Debug)]
pub struct Flow {
    pub path: Path,
    pub bytes: f64,
    /// Virtual time (seconds) when the flow is issued.
    pub issue_t: f64,
    pub mode: XferMode,
    /// Extra per-flow rate derating (e.g. non-affine GPU↔HCA access
    /// over the PCIe host bridge in the UCX baseline). 1.0 = none.
    pub rate_factor: f64,
}

impl Flow {
    pub fn new(path: Path, bytes: f64) -> Flow {
        Flow { path, bytes, issue_t: 0.0, mode: XferMode::Kernel, rate_factor: 1.0 }
    }
    pub fn with_rate_factor(mut self, f: f64) -> Flow {
        self.rate_factor = f;
        self
    }
    pub fn at(mut self, t: f64) -> Flow {
        self.issue_t = t;
        self
    }
    pub fn with_mode(mut self, m: XferMode) -> Flow {
        self.mode = m;
        self
    }
}

/// Per-flow outcome.
#[derive(Clone, Debug)]
pub struct FlowResult {
    /// When the flow's pipeline began moving data (issue + setup).
    pub start_t: f64,
    /// When the last byte landed.
    pub finish_t: f64,
    pub bytes: f64,
}

impl FlowResult {
    /// Achieved end-to-end bandwidth in GB/s (counted from issue).
    pub fn gbps_from(&self, issue_t: f64) -> f64 {
        self.bytes / (self.finish_t - issue_t) / 1e9
    }
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub flows: Vec<FlowResult>,
    /// Total bytes carried per link over the run.
    pub link_bytes: Vec<f64>,
    /// Time the last flow finished.
    pub makespan: f64,
}

impl SimResult {
    /// Per-link utilization (fraction of capacity × makespan used),
    /// restricted to links that carried any traffic.
    pub fn link_utilization(&self, topo: &Topology) -> Vec<(usize, f64)> {
        self.link_bytes
            .iter()
            .enumerate()
            .filter(|(_, &b)| b > 0.0)
            .map(|(i, &b)| {
                let cap = gbps_to_bps(topo.link(i).cap_gbps);
                (i, b / (cap * self.makespan.max(1e-12)))
            })
            .collect()
    }

    /// Aggregate achieved bandwidth (GB/s) across a set of flows that
    /// all started at t=0: total bytes / makespan.
    pub fn aggregate_gbps(&self) -> f64 {
        let total: f64 = self.flows.iter().map(|f| f.bytes).sum();
        total / self.makespan.max(1e-12) / 1e9
    }
}

/// Internal: one capacity constraint (bytes/s) over a set of flows.
struct Constraint {
    cap: f64,
    members: Vec<usize>,
}

/// The fluid fabric simulator.
pub struct FluidSim<'a> {
    pub topo: &'a Topology,
    pub params: FabricParams,
}

impl<'a> FluidSim<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams) -> Self {
        FluidSim { topo, params }
    }

    /// Run all flows to completion; returns per-flow finish times and
    /// per-link byte totals.
    pub fn run(&self, flows: &[Flow]) -> SimResult {
        let n = flows.len();
        let mut start_t = vec![0.0f64; n];
        for (i, f) in flows.iter().enumerate() {
            start_t[i] = f.issue_t + self.params.start_latency_s(&f.path, f.mode);
        }
        let mut remaining: Vec<f64> = flows.iter().map(|f| f.bytes.max(1.0)).collect();
        let mut finish_t = vec![f64::NAN; n];
        let mut link_bytes = vec![0.0f64; self.topo.links.len()];

        // Static constraint structure over ALL flows; the rate solver
        // only considers currently-active members.
        let constraints = self.build_constraints(flows);
        // reverse index: constraints each flow belongs to (hot-loop aid)
        let mut flow_cons: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (ci, c) in constraints.iter().enumerate() {
            for &m in &c.members {
                flow_cons[m].push(ci);
            }
        }
        let rate_cap: Vec<f64> = flows
            .iter()
            .map(|f| {
                gbps_to_bps(self.params.flow_rate_cap_gbps(self.topo, &f.path, f.bytes))
                    * f.rate_factor
            })
            .collect();

        let mut t = 0.0f64;
        let mut active: Vec<usize> = Vec::new();
        let mut pending: Vec<usize> = (0..n).collect();
        pending.sort_by(|&a, &b| start_t[a].partial_cmp(&start_t[b]).unwrap());
        pending.reverse(); // pop from the back = earliest

        let mut rates = vec![0.0f64; n];
        while !active.is_empty() || !pending.is_empty() {
            // admit arrivals at the current time
            while let Some(&i) = pending.last() {
                if start_t[i] <= t + 1e-15 {
                    active.push(i);
                    pending.pop();
                } else {
                    break;
                }
            }
            if active.is_empty() {
                t = start_t[*pending.last().unwrap()];
                continue;
            }
            self.max_min_rates(&constraints, &flow_cons, &rate_cap, &active, &mut rates);
            // next event: earliest completion or next arrival
            let mut dt = f64::INFINITY;
            for &i in &active {
                if rates[i] > 0.0 {
                    dt = dt.min(remaining[i] / rates[i]);
                }
            }
            if let Some(&i) = pending.last() {
                dt = dt.min(start_t[i] - t);
            }
            assert!(dt.is_finite(), "stuck: no progress possible (all rates zero)");
            // advance
            for &i in &active {
                let moved = rates[i] * dt;
                remaining[i] -= moved;
                for &h in &flows[i].path.hops {
                    link_bytes[h] += moved;
                }
            }
            t += dt;
            // retire completions
            active.retain(|&i| {
                if remaining[i] <= 1e-6 {
                    finish_t[i] = t;
                    false
                } else {
                    true
                }
            });
        }

        let makespan = finish_t.iter().cloned().fold(0.0, f64::max);
        SimResult {
            flows: (0..n)
                .map(|i| FlowResult {
                    start_t: start_t[i],
                    finish_t: finish_t[i],
                    bytes: flows[i].bytes,
                })
                .collect(),
            link_bytes,
            makespan,
        }
    }

    /// Assemble every capacity constraint touching any flow.
    fn build_constraints(&self, flows: &[Flow]) -> Vec<Constraint> {
        let p = &self.params;
        let mut out = Vec::new();
        // per-link
        let mut link_members: Vec<Vec<usize>> = vec![Vec::new(); self.topo.links.len()];
        // per-GPU inject/recv
        let g = self.topo.num_gpus();
        let mut inj: Vec<Vec<usize>> = vec![Vec::new(); g];
        let mut rcv: Vec<Vec<usize>> = vec![Vec::new(); g];
        // per-node net out/in
        let nn = self.topo.nodes;
        let mut net_out: Vec<Vec<usize>> = vec![Vec::new(); nn];
        let mut net_in: Vec<Vec<usize>> = vec![Vec::new(); nn];

        for (i, f) in flows.iter().enumerate() {
            for &h in &f.path.hops {
                link_members[h].push(i);
                let l = self.topo.link(h);
                if !matches!(l.kind, LinkKind::NvLink) {
                    net_out[self.topo.node_of(l.src)].push(i);
                    net_in[self.topo.node_of(l.dst)].push(i);
                }
            }
            inj[f.path.src].push(i);
            rcv[f.path.dst].push(i);
        }
        for (id, members) in link_members.into_iter().enumerate() {
            if !members.is_empty() {
                out.push(Constraint {
                    cap: gbps_to_bps(self.topo.link(id).cap_gbps),
                    members,
                });
            }
        }
        for members in inj {
            if members.len() > 1 {
                out.push(Constraint { cap: gbps_to_bps(p.inject_cap_gbps), members });
            }
        }
        for members in rcv {
            if members.len() > 1 {
                out.push(Constraint { cap: gbps_to_bps(p.recv_cap_gbps), members });
            }
        }
        for members in net_out.into_iter().chain(net_in) {
            if members.len() > 1 {
                out.push(Constraint { cap: gbps_to_bps(p.node_net_cap_gbps), members });
            }
        }
        out
    }

    /// Water-filling max-min fair rates for the active flow set.
    /// `flow_cons[i]` lists the constraints flow `i` belongs to.
    fn max_min_rates(
        &self,
        constraints: &[Constraint],
        flow_cons: &[Vec<usize>],
        rate_cap: &[f64],
        active: &[usize],
        rates: &mut [f64],
    ) {
        for r in rates.iter_mut() {
            *r = 0.0;
        }
        let mut frozen: Vec<bool> = vec![true; rates.len()];
        for &i in active {
            frozen[i] = false;
        }
        // residual capacity + live member count per constraint
        let mut residual: Vec<f64> = constraints.iter().map(|c| c.cap).collect();
        let mut live: Vec<usize> = constraints
            .iter()
            .map(|c| c.members.iter().filter(|&&m| !frozen[m]).count())
            .collect();
        let mut level = 0.0f64; // common rate level of unfrozen flows
        let mut n_unfrozen = active.len();
        while n_unfrozen > 0 {
            // headroom per constraint: residual / live members
            let mut delta = f64::INFINITY;
            for ci in 0..constraints.len() {
                if live[ci] > 0 {
                    delta = delta.min(residual[ci] / live[ci] as f64);
                }
            }
            // per-flow ceilings
            for (i, &f) in frozen.iter().enumerate() {
                if !f {
                    delta = delta.min(rate_cap[i] - level);
                }
            }
            if !delta.is_finite() {
                // no binding constraint: everyone rides their own cap
                delta = 0.0;
            }
            let delta = delta.max(0.0);
            level += delta;
            // charge constraints
            for ci in 0..constraints.len() {
                if live[ci] > 0 {
                    residual[ci] -= delta * live[ci] as f64;
                }
            }
            // freeze: flows at their cap, or in a saturated constraint
            let mut newly_frozen = Vec::new();
            for &i in active {
                if !frozen[i] && rate_cap[i] - level <= 1e-9 {
                    newly_frozen.push(i);
                }
            }
            for (ci, c) in constraints.iter().enumerate() {
                if live[ci] > 0 && residual[ci] <= 1e-9 {
                    for &m in &c.members {
                        if !frozen[m] {
                            newly_frozen.push(m);
                        }
                    }
                }
            }
            if newly_frozen.is_empty() {
                // numerical corner: freeze everything at current level
                for &i in active {
                    if !frozen[i] {
                        rates[i] = level;
                        frozen[i] = true;
                    }
                }
                break;
            }
            newly_frozen.sort_unstable();
            newly_frozen.dedup();
            for i in newly_frozen {
                if frozen[i] {
                    continue;
                }
                rates[i] = level;
                frozen[i] = true;
                n_unfrozen -= 1;
                for &ci in &flow_cons[i] {
                    if live[ci] > 0 {
                        live[ci] -= 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;
    use crate::topology::Topology;

    const MB: f64 = 1024.0 * 1024.0;

    fn sim(topo: &Topology) -> FluidSim<'_> {
        FluidSim::new(topo, FabricParams::default())
    }

    /// Fig 6a anchor: direct NVLink large-message ≈ 120 GB/s.
    #[test]
    fn direct_nvlink_saturates() {
        let t = Topology::paper();
        let s = sim(&t);
        let path = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[Flow::new(path, 1024.0 * MB)]);
        let bw = r.aggregate_gbps();
        assert!(bw > 115.0 && bw <= 120.0, "bw={bw}");
    }

    /// Fig 6a anchor: direct + 1 relay ⇒ ≈213 GB/s; +2 relays ⇒ ≈278 GB/s.
    #[test]
    fn multipath_intra_matches_paper_anchors() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 1, true);
        let big = 512.0 * MB;
        // direct + via-2, bytes split ∝ the achievable per-path rates
        // (120 : ρ·120) so the two flows drain together.
        let r2 = s.run(&[
            Flow::new(cands[0].clone(), big),
            Flow::new(cands[1].clone(), big * 0.776),
        ]);
        let bw2 = (big + big * 0.776) / r2.makespan / 1e9;
        assert!((bw2 - 213.1).abs() < 8.0, "2-path bw={bw2}");
        // direct + via-2 + via-3: the source injection cap (278.2)
        // binds and max-min equalizes the three flows near 92.7 GB/s
        // each, so an equal byte split drains together. The AGGREGATE
        // is the paper's 278.2 anchor.
        let r3 = s.run(&[
            Flow::new(cands[0].clone(), big),
            Flow::new(cands[1].clone(), big),
            Flow::new(cands[2].clone(), big),
        ]);
        let bw3 = (3.0 * big) / r3.makespan / 1e9;
        assert!((bw3 - 278.2).abs() < 10.0, "3-path bw={bw3}");
    }

    /// Fig 6b anchor: 1 rail ≈45.1, 4 rails ≈170 GB/s aggregate.
    #[test]
    fn multirail_inter_matches_paper_anchors() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 4, true); // gpu0 → gpu4 (node1, rail0)
        let big = 512.0 * MB;
        let r1 = s.run(&[Flow::new(cands[0].clone(), big)]);
        let bw1 = r1.aggregate_gbps();
        assert!((bw1 - 45.1).abs() < 2.0, "1 rail bw={bw1}");
        let flows: Vec<Flow> =
            cands.iter().map(|p| Flow::new(p.clone(), big)).collect();
        let r4 = s.run(&flows);
        let bw4 = (4.0 * big) / r4.makespan / 1e9;
        assert!((bw4 - 170.0).abs() < 6.0, "4 rails bw={bw4}");
    }

    #[test]
    fn link_byte_conservation() {
        let t = Topology::paper();
        let s = sim(&t);
        let path = candidates(&t, 0, 1, true).remove(1); // 2-hop
        let bytes = 64.0 * MB;
        let r = s.run(&[Flow::new(path, bytes)]);
        // each of the 2 hops carries the full payload
        let total: f64 = r.link_bytes.iter().sum();
        assert!((total - 2.0 * bytes).abs() < 1.0, "total={total}");
    }

    #[test]
    fn fair_share_two_flows_one_link() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        // two equal flows over the same NVLink edge finish together at
        // half rate each.
        let r = s.run(&[
            Flow::new(p.clone(), 256.0 * MB),
            Flow::new(p.clone(), 256.0 * MB),
        ]);
        let d = (r.flows[0].finish_t - r.flows[1].finish_t).abs();
        assert!(d < 1e-6, "finish skew {d}");
        let bw = r.aggregate_gbps();
        assert!(bw <= 120.0 + 1e-6 && bw > 110.0, "bw={bw}");
    }

    #[test]
    fn staggered_arrivals_progress() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[
            Flow::new(p.clone(), 64.0 * MB),
            Flow::new(p.clone(), 64.0 * MB).at(0.01),
        ]);
        assert!(r.flows[1].finish_t > r.flows[0].finish_t);
        assert!(r.makespan >= 0.01);
    }

    #[test]
    fn small_message_latency_dominated() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[Flow::new(p, 64.0 * 1024.0)]); // 64 KB
        let bw = r.aggregate_gbps();
        // far from peak: overhead + unsaturated curve
        assert!(bw < 10.0, "bw={bw}");
    }

    #[test]
    fn node_net_cap_binds_aggregate() {
        let t = Topology::paper();
        let s = sim(&t);
        // all 4 GPUs of node 0 send to their rail peers simultaneously
        let mut flows = Vec::new();
        for g in 0..4 {
            let p = candidates(&t, g, g + 4, false).remove(0);
            flows.push(Flow::new(p, 512.0 * MB));
        }
        let r = s.run(&flows);
        let agg = (4.0 * 512.0 * MB) / r.makespan / 1e9;
        assert!(agg <= 170.0 + 1.0, "agg={agg}");
        assert!(agg > 160.0, "agg={agg}");
    }
}
