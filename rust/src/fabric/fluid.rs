//! Flow-level fluid simulator with progressive filling.
//!
//! Flows are (path, bytes, start-time, transfer-mode) tuples. Between
//! events (flow arrival / completion) every active flow transfers at a
//! constant rate given by **max-min fair sharing** over the fabric's
//! capacity constraints:
//!
//! * per-link capacity (NVLink edge, NIC rail, cross-rail),
//! * per-GPU injection cap (HBM read + copy kernels at the source),
//! * per-GPU receive cap (HBM write at the destination),
//! * per-node aggregate NIC cap (in each direction),
//! * per-flow rate ceiling (size efficiency × bottleneck × relay ρ).
//!
//! The water-filling computation raises all unfrozen flows' rates
//! uniformly until some constraint saturates, freezes that
//! constraint's flows, and repeats — the textbook max-min allocation
//! generalized to multiple resource kinds.
//!
//! Two execution surfaces share the same event loop:
//!
//! * [`FluidSim::run`] — run a fixed flow set to completion (the
//!   closed-form path used by every static experiment);
//! * [`SimEngine`] — the resumable form behind the execution-time
//!   re-planning loop (paper §I/§IV "execution-time planning"):
//!   advance virtual time in bounded steps, sample per-link byte
//!   windows for the monitor, **preempt** a flow's residual bytes and
//!   re-issue them on different paths at a replan epoch.
//!
//! ## Two interchangeable solvers, one trajectory
//!
//! The per-event max-min computation exists twice (see [`SolverKind`]):
//!
//! * [`SolverKind::Reference`] — the from-scratch water-filler
//!   (`FluidSim::max_min_rates`): every event rebuilds the live
//!   member count of every constraint from the full membership lists
//!   (O(Σ|members|)) and scans every constraint and every flow per
//!   freeze round. Kept as the equivalence oracle for the property
//!   suite and as the pre-PR baseline `nimble scale` measures against.
//! * [`SolverKind::Incremental`] (the default) — [`SimEngine`]
//!   maintains per-constraint *active-member counts*, the list of
//!   constraints that currently have active members, and a
//!   rate-cap-sorted index of active flows **across events**; within a
//!   solve, the linear "find min headroom" scan is replaced by a lazy
//!   min-heap over conservative headroom keys, so only the binding
//!   constraints are ever charged (their exact charge history is
//!   replayed on demand). Per event it performs the exact same
//!   floating-point operations as the reference solver (same deltas,
//!   same charge order, same freeze sets), so the two trajectories are
//!   **bit-identical** — `prop_incremental_waterfill_matches_reference`
//!   in `tests/fabric_props.rs` holds this invariant under randomized
//!   preempt/add_flows sequences.

use super::backend::{reduce_blame, BlameKey, WindowAttr};
use super::{faults, gbps_to_bps, FabricParams, XferMode};
use crate::topology::{Path, Topology};
use std::collections::BTreeMap;

/// One transfer request routed over a fixed path.
#[derive(Clone, Debug)]
pub struct Flow {
    pub path: Path,
    pub bytes: f64,
    /// Virtual time (seconds) when the flow is issued.
    pub issue_t: f64,
    pub mode: XferMode,
    /// Extra per-flow rate derating (e.g. non-affine GPU↔HCA access
    /// over the PCIe host bridge in the UCX baseline). 1.0 = none.
    pub rate_factor: f64,
    /// Opaque owner tag (the multi-tenant orchestrator stamps the
    /// tenant/job id). Never affects simulation dynamics; backends
    /// that record per-chunk observations group them by it
    /// ([`crate::fabric::TailStats::per_tag_sojourn`]). 0 = untagged.
    pub tag: u64,
}

impl Flow {
    pub fn new(path: Path, bytes: f64) -> Flow {
        Flow {
            path,
            bytes,
            issue_t: 0.0,
            mode: XferMode::Kernel,
            rate_factor: 1.0,
            tag: 0,
        }
    }
    pub fn with_rate_factor(mut self, f: f64) -> Flow {
        self.rate_factor = f;
        self
    }
    pub fn at(mut self, t: f64) -> Flow {
        self.issue_t = t;
        self
    }
    pub fn with_mode(mut self, m: XferMode) -> Flow {
        self.mode = m;
        self
    }
    /// Stamp the owner tag (tenant/job id).
    pub fn tagged(mut self, tag: u64) -> Flow {
        self.tag = tag;
        self
    }
}

/// Per-flow outcome.
#[derive(Clone, Debug)]
pub struct FlowResult {
    /// When the flow's pipeline began moving data (issue + setup).
    pub start_t: f64,
    /// When the last byte landed.
    pub finish_t: f64,
    pub bytes: f64,
}

impl FlowResult {
    /// Achieved end-to-end bandwidth in GB/s (counted from issue).
    pub fn gbps_from(&self, issue_t: f64) -> f64 {
        self.bytes / (self.finish_t - issue_t) / 1e9
    }
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub flows: Vec<FlowResult>,
    /// Total bytes carried per link over the run.
    pub link_bytes: Vec<f64>,
    /// Time the last flow finished.
    pub makespan: f64,
}

impl SimResult {
    /// Per-link utilization (fraction of capacity × makespan used),
    /// restricted to links that carried any traffic.
    pub fn link_utilization(&self, topo: &Topology) -> Vec<(usize, f64)> {
        self.link_bytes
            .iter()
            .enumerate()
            .filter(|(_, &b)| b > 0.0)
            .map(|(i, &b)| {
                let cap = gbps_to_bps(topo.link(i).cap_gbps);
                (i, b / (cap * self.makespan.max(1e-12)))
            })
            .collect()
    }

    /// Aggregate achieved bandwidth (GB/s) across a set of flows that
    /// all started at t=0: total bytes / makespan.
    pub fn aggregate_gbps(&self) -> f64 {
        let total: f64 = self.flows.iter().map(|f| f.bytes).sum();
        total / self.makespan.max(1e-12) / 1e9
    }
}

/// Identity of a capacity constraint: which physical resource it
/// models. Fault application ([`SimEngine::apply_fault`]) keys its
/// capacity rescaling off this; fault-free runs never read it.
#[derive(Clone, Copy, Debug)]
enum ConsKey {
    Link(usize),
    Inj(usize),
    Rcv(usize),
    NetOut(usize),
    NetIn(usize),
}

/// Internal: one capacity constraint (bytes/s) over a set of flows.
struct Constraint {
    key: ConsKey,
    cap: f64,
    members: Vec<usize>,
}

/// The fluid fabric simulator.
pub struct FluidSim<'a> {
    pub topo: &'a Topology,
    pub params: FabricParams,
}

impl<'a> FluidSim<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams) -> Self {
        FluidSim { topo, params }
    }

    /// Run all flows to completion; returns per-flow finish times and
    /// per-link byte totals. Implemented on [`SimEngine`] so the static
    /// path and the resumable re-planning path share one event loop.
    pub fn run(&self, flows: &[Flow]) -> SimResult {
        let mut engine = SimEngine::new(self.topo, self.params.clone(), flows);
        engine.run_to_completion();
        engine.result()
    }

    /// Assemble every capacity constraint touching any flow.
    ///
    /// `inj_force`: per-GPU injection scales from the fault layer —
    /// a scaled (straggling) GPU gets an injection constraint even
    /// with a single member flow, because its throttled cap can bind
    /// where the healthy cap never could. `None` (every fault-free
    /// run) reproduces the original constraint set exactly.
    fn build_constraints(&self, flows: &[Flow], inj_force: Option<&[f64]>) -> Vec<Constraint> {
        let p = &self.params;
        let mut out = Vec::new();
        // per-link
        let mut link_members: Vec<Vec<usize>> = vec![Vec::new(); self.topo.links.len()];
        // per-GPU inject/recv
        let g = self.topo.num_gpus();
        let mut inj: Vec<Vec<usize>> = vec![Vec::new(); g];
        let mut rcv: Vec<Vec<usize>> = vec![Vec::new(); g];
        // per-node net out/in
        let nn = self.topo.nodes;
        let mut net_out: Vec<Vec<usize>> = vec![Vec::new(); nn];
        let mut net_in: Vec<Vec<usize>> = vec![Vec::new(); nn];

        for (i, f) in flows.iter().enumerate() {
            for &h in &f.path.hops {
                link_members[h].push(i);
                let l = self.topo.link(h);
                // a hop consumes its nodes' NIC budget only where it
                // actually crosses a NIC (on flat fabrics: every
                // non-NVLink hop at both ends, exactly the old rule)
                if let Some(n) = self.topo.nic_out_node(l) {
                    net_out[n].push(i);
                }
                if let Some(n) = self.topo.nic_in_node(l) {
                    net_in[n].push(i);
                }
            }
            inj[f.path.src].push(i);
            rcv[f.path.dst].push(i);
        }
        for (id, members) in link_members.into_iter().enumerate() {
            if !members.is_empty() {
                out.push(Constraint {
                    key: ConsKey::Link(id),
                    cap: gbps_to_bps(self.topo.link(id).cap_gbps),
                    members,
                });
            }
        }
        for (g, members) in inj.into_iter().enumerate() {
            let forced = inj_force.map_or(false, |s| s[g] < 1.0);
            if members.len() > 1 || (forced && !members.is_empty()) {
                out.push(Constraint {
                    key: ConsKey::Inj(g),
                    cap: gbps_to_bps(p.inject_cap_gbps),
                    members,
                });
            }
        }
        for (g, members) in rcv.into_iter().enumerate() {
            if members.len() > 1 {
                out.push(Constraint {
                    key: ConsKey::Rcv(g),
                    cap: gbps_to_bps(p.recv_cap_gbps),
                    members,
                });
            }
        }
        for (n, members) in net_out.into_iter().enumerate() {
            if members.len() > 1 {
                out.push(Constraint {
                    key: ConsKey::NetOut(n),
                    cap: gbps_to_bps(p.node_net_cap_gbps),
                    members,
                });
            }
        }
        for (n, members) in net_in.into_iter().enumerate() {
            if members.len() > 1 {
                out.push(Constraint {
                    key: ConsKey::NetIn(n),
                    cap: gbps_to_bps(p.node_net_cap_gbps),
                    members,
                });
            }
        }
        out
    }

    /// Water-filling max-min fair rates for the active flow set.
    /// `flow_cons[i]` lists the constraints flow `i` belongs to.
    ///
    /// This is the **reference** (from-scratch) solver: it derives all
    /// per-constraint state from the membership lists on every call.
    /// The engine's default is the incremental solver, which must stay
    /// bit-identical to this one ([`SolverKind`]).
    fn max_min_rates(
        &self,
        constraints: &[Constraint],
        flow_cons: &[Vec<usize>],
        rate_cap: &[f64],
        active: &[usize],
        rates: &mut [f64],
    ) {
        for r in rates.iter_mut() {
            *r = 0.0;
        }
        let mut frozen: Vec<bool> = vec![true; rates.len()];
        for &i in active {
            frozen[i] = false;
        }
        // residual capacity + live member count per constraint
        let mut residual: Vec<f64> = constraints.iter().map(|c| c.cap).collect();
        let mut live: Vec<usize> = constraints
            .iter()
            .map(|c| c.members.iter().filter(|&&m| !frozen[m]).count())
            .collect();
        let mut level = 0.0f64; // common rate level of unfrozen flows
        let mut n_unfrozen = active.len();
        while n_unfrozen > 0 {
            // headroom per constraint: residual / live members
            let mut delta = f64::INFINITY;
            for ci in 0..constraints.len() {
                if live[ci] > 0 {
                    delta = delta.min(residual[ci] / live[ci] as f64);
                }
            }
            // per-flow ceilings
            for (i, &f) in frozen.iter().enumerate() {
                if !f {
                    delta = delta.min(rate_cap[i] - level);
                }
            }
            if !delta.is_finite() {
                // no binding constraint: everyone rides their own cap
                delta = 0.0;
            }
            let delta = delta.max(0.0);
            level += delta;
            // charge constraints
            for ci in 0..constraints.len() {
                if live[ci] > 0 {
                    residual[ci] -= delta * live[ci] as f64;
                }
            }
            // freeze: flows at their cap, or in a saturated constraint
            let mut newly_frozen = Vec::new();
            for &i in active {
                if !frozen[i] && rate_cap[i] - level <= 1e-9 {
                    newly_frozen.push(i);
                }
            }
            for (ci, c) in constraints.iter().enumerate() {
                if live[ci] > 0 && residual[ci] <= 1e-9 {
                    for &m in &c.members {
                        if !frozen[m] {
                            newly_frozen.push(m);
                        }
                    }
                }
            }
            if newly_frozen.is_empty() {
                // numerical corner: freeze everything at current level
                for &i in active {
                    if !frozen[i] {
                        rates[i] = level;
                        frozen[i] = true;
                    }
                }
                break;
            }
            newly_frozen.sort_unstable();
            newly_frozen.dedup();
            for i in newly_frozen {
                if frozen[i] {
                    continue;
                }
                rates[i] = level;
                frozen[i] = true;
                n_unfrozen -= 1;
                for &ci in &flow_cons[i] {
                    // an unfrozen member was counted in `live` when the
                    // solve started, so the count must still cover it —
                    // a zero here means the freeze accounting skipped
                    // or double-counted a flow somewhere upstream.
                    debug_assert!(live[ci] > 0, "double-freeze accounting on constraint {ci}");
                    live[ci] -= 1;
                }
            }
        }
    }
}

/// Which max-min solver drives the engine's event loop.
///
/// Both produce bit-identical trajectories; they differ only in how
/// much per-event work they redo. See the module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Incremental water-filler (the default): constraint liveness and
    /// the flow rate-cap order are maintained across events, so an
    /// event touches only live state.
    Incremental,
    /// From-scratch solver rebuilt from the membership lists on every
    /// event — the pre-PR behavior, kept as the equivalence oracle and
    /// as the baseline the `nimble scale` speedup is measured against.
    Reference,
}

/// Resumable fluid-simulation engine: the mechanism under the
/// execution-time re-planning loop.
///
/// The engine owns the event loop of [`FluidSim::run`] but exposes it
/// incrementally:
///
/// * [`SimEngine::advance_to`] runs events up to a virtual-time bound
///   (a replan epoch boundary);
/// * [`SimEngine::take_window`] drains the per-link byte counters
///   accumulated since the previous call (the monitor's sampling
///   window);
/// * [`SimEngine::preempt`] stops a flow mid-transfer and returns its
///   residual bytes so the coordinator can re-issue them on new paths
///   via [`SimEngine::add_flows`].
///
/// With no preemptions or additions, `run_to_completion` reproduces
/// [`FluidSim::run`] event-for-event (bit-identical results) — the
/// guarantee behind "replanning disabled ⇒ byte-identical to the
/// static path".
///
/// `SimEngine` is the default [`crate::fabric::FabricBackend`]
/// implementation (`[fabric.packet] backend = "fluid"`); the trait impl
/// in `fabric::backend` delegates to the inherent methods below, so
/// driving the engine through the trait object is the same code path,
/// operation for operation.
pub struct SimEngine<'a> {
    sim: FluidSim<'a>,
    flows: Vec<Flow>,
    start_t: Vec<f64>,
    remaining: Vec<f64>,
    moved: Vec<f64>,
    finish_t: Vec<f64>,
    link_bytes: Vec<f64>,
    /// Per-flow bytes moved since the last window drain. The fluid
    /// engine adds the identical `rate·dt` to every hop of a flow, so
    /// one counter per flow is the full attribution: the per-link
    /// window totals are recovered by the canonical blame reduction
    /// ([`reduce_blame`], DESIGN.md §16), which runs identically
    /// whether or not attribution is requested.
    win_flow: Vec<f64>,
    t: f64,
    active: Vec<usize>,
    /// Sorted by start time, descending (pop from the back = earliest).
    pending: Vec<usize>,
    constraints: Vec<Constraint>,
    flow_cons: Vec<Vec<usize>>,
    rate_cap: Vec<f64>,
    rates: Vec<f64>,
    /// Flows preempted before completing (residual re-issued elsewhere).
    preempted: Vec<bool>,
    /// Per-link capacity scale under faults (1 healthy, 0 dead).
    link_scale: Vec<f64>,
    /// Per-GPU injection-cap scale under faults (straggler nodes).
    inject_scale: Vec<f64>,
    /// Whether any fault has ever been applied. `false` keeps every
    /// rebuild on the exact pre-fault arithmetic, so fault-free runs
    /// stay bit-identical to builds without the fault layer.
    faulted: bool,
    solver: SolverKind,
    /// Rate solves performed (one per event-loop step with active flows).
    events: u64,
    // ---- incremental water-filler state (SolverKind::Incremental) ----
    /// Constraint capacities, flat (mirrors `constraints[..].cap`).
    cons_cap: Vec<f64>,
    /// Currently-active members per constraint, maintained as flows
    /// are admitted / finish / are preempted.
    active_count: Vec<u32>,
    /// Constraints with `active_count > 0` (lazily pruned).
    hot: Vec<usize>,
    in_hot: Vec<bool>,
    /// Persistent freeze flags: true for every flow outside a solve;
    /// a solve unfreezes the active set and re-freezes it completely.
    frozen: Vec<bool>,
    /// Active flows sorted by (rate_cap, index): the per-flow-ceiling
    /// minimum is the first unfrozen entry, and cap-frozen flows are a
    /// prefix of the unfrozen subsequence.
    cap_sorted: Vec<usize>,
    newly_frozen: Vec<usize>,
    // per-constraint lazy-replay state (see solve_incremental)
    cons_residual: Vec<f64>,
    cons_hist_idx: Vec<u32>,
    cons_live: Vec<u32>,
    cons_ev_pos: Vec<Vec<u32>>,
    cons_ev_cursor: Vec<u32>,
    cons_eval_round: Vec<u64>,
    cons_vkey_bits: Vec<u64>,
    /// Per-solve history of water-level increments (δ per round).
    history: Vec<f64>,
    heap_buf: Vec<std::cmp::Reverse<(u64, u32)>>,
    evaluated_buf: Vec<usize>,
    round_counter: u64,
}

/// Conservative window (bytes/s) for the lazy min-headroom heap: must
/// exceed the accumulated FP drift of the replayed subtraction
/// sequence (≤ ~1e-2 for realistic round counts) while staying far
/// below real headroom gaps (~1e8+). Only affects how many constraints
/// get an exact evaluation per round — never the solution.
const HEADROOM_SLACK: f64 = 1.0e4;

/// Heap-key sentinel meaning "no live heap entry for this constraint".
/// Valid keys are finite non-negative f64 bit patterns, always < MAX.
const NO_KEY: u64 = u64::MAX;

impl<'a> SimEngine<'a> {
    pub fn new(topo: &'a Topology, params: FabricParams, flows: &[Flow]) -> Self {
        let mut e = SimEngine {
            sim: FluidSim { topo, params },
            flows: Vec::new(),
            start_t: Vec::new(),
            remaining: Vec::new(),
            moved: Vec::new(),
            finish_t: Vec::new(),
            link_bytes: vec![0.0; topo.links.len()],
            win_flow: Vec::new(),
            t: 0.0,
            active: Vec::new(),
            pending: Vec::new(),
            constraints: Vec::new(),
            flow_cons: Vec::new(),
            rate_cap: Vec::new(),
            rates: Vec::new(),
            preempted: Vec::new(),
            link_scale: vec![1.0; topo.links.len()],
            inject_scale: vec![1.0; topo.num_gpus()],
            faulted: false,
            solver: SolverKind::Incremental,
            events: 0,
            cons_cap: Vec::new(),
            active_count: Vec::new(),
            hot: Vec::new(),
            in_hot: Vec::new(),
            frozen: Vec::new(),
            cap_sorted: Vec::new(),
            newly_frozen: Vec::new(),
            cons_residual: Vec::new(),
            cons_hist_idx: Vec::new(),
            cons_live: Vec::new(),
            cons_ev_pos: Vec::new(),
            cons_ev_cursor: Vec::new(),
            cons_eval_round: Vec::new(),
            cons_vkey_bits: Vec::new(),
            history: Vec::new(),
            heap_buf: Vec::new(),
            evaluated_buf: Vec::new(),
            round_counter: 0,
        };
        e.add_flows(flows);
        e
    }

    /// Select the max-min solver (default [`SolverKind::Incremental`]).
    /// Both solvers produce bit-identical trajectories; the switch
    /// exists for the equivalence property suite and the `nimble scale`
    /// baseline measurement.
    pub fn set_solver(&mut self, solver: SolverKind) {
        self.solver = solver;
    }

    /// Number of rate solves performed so far (the event count the
    /// scale experiments report as events/sec).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.t
    }

    /// All flows delivered or preempted.
    pub fn is_done(&self) -> bool {
        self.active.is_empty() && self.pending.is_empty()
    }

    /// Bytes flow `i` still has to deliver (0 once finished/preempted).
    pub fn residual_bytes(&self, i: usize) -> f64 {
        if self.finish_t[i].is_nan() {
            self.remaining[i].max(0.0)
        } else {
            0.0
        }
    }

    /// Bytes flow `i` has actually moved so far.
    pub fn moved_bytes(&self, i: usize) -> f64 {
        self.moved[i]
    }

    /// Whether flow `i` is still in flight (issued or queued).
    pub fn is_live(&self, i: usize) -> bool {
        self.finish_t[i].is_nan()
    }

    /// The flow registered under index `i` (issue order).
    pub fn flow(&self, i: usize) -> &Flow {
        &self.flows[i]
    }

    /// Register additional flows (re-issued residuals at a replan
    /// epoch). Their `issue_t` should not precede [`SimEngine::now`].
    /// Returns the index of the first newly added flow.
    pub fn add_flows(&mut self, flows: &[Flow]) -> usize {
        let first = self.flows.len();
        for f in flows {
            let i = self.flows.len();
            self.start_t
                .push(f.issue_t + self.sim.params.start_latency_s(&f.path, f.mode));
            self.remaining.push(f.bytes.max(1.0));
            self.moved.push(0.0);
            self.win_flow.push(0.0);
            self.finish_t.push(f64::NAN);
            self.preempted.push(false);
            self.flows.push(f.clone());
            self.pending.push(i);
        }
        self.rebuild();
        first
    }

    /// Rebuild the constraint structure + solver state over the full
    /// flow set — after an [`SimEngine::add_flows`] batch or a fault
    /// rescaling ([`SimEngine::apply_fault`]). Pure code motion from
    /// the original `add_flows` tail, so the fault-free trajectory is
    /// unchanged.
    fn rebuild(&mut self) {
        // Rebuild the constraint structure over the full flow set (the
        // solver only ever raises rates of *active* members, so closed
        // flows in a membership list are inert).
        self.constraints = self.sim.build_constraints(
            &self.flows,
            if self.faulted { Some(&self.inject_scale) } else { None },
        );
        if self.faulted {
            // re-price faulted resources; untouched keys keep the
            // capacities build_constraints just computed
            for c in &mut self.constraints {
                match c.key {
                    ConsKey::Link(l) => {
                        c.cap = gbps_to_bps(
                            self.sim.topo.link(l).cap_gbps * self.link_scale[l],
                        );
                    }
                    ConsKey::Inj(g) => {
                        c.cap = gbps_to_bps(
                            self.sim.params.inject_cap_gbps * self.inject_scale[g],
                        );
                    }
                    _ => {}
                }
            }
        }
        self.flow_cons = vec![Vec::new(); self.flows.len()];
        for (ci, c) in self.constraints.iter().enumerate() {
            for &m in &c.members {
                self.flow_cons[m].push(ci);
            }
        }
        self.rate_cap = self
            .flows
            .iter()
            .map(|f| {
                gbps_to_bps(self.sim.params.flow_rate_cap_gbps(
                    self.sim.topo,
                    &f.path,
                    f.bytes,
                )) * f.rate_factor
            })
            .collect();
        self.rates = vec![0.0; self.flows.len()];
        let start_t = &self.start_t;
        self.pending
            .sort_by(|&a, &b| start_t[a].partial_cmp(&start_t[b]).unwrap());
        self.pending.reverse(); // pop from the back = earliest
        // Re-seed the incremental solver's cross-event state: caps,
        // active-member counts and the rate-cap order of the flows
        // already in flight (rate caps are recomputed from the same
        // inputs, so re-activation reproduces the same keys).
        let nc = self.constraints.len();
        self.cons_cap = self.constraints.iter().map(|c| c.cap).collect();
        self.active_count = vec![0; nc];
        self.in_hot = vec![false; nc];
        self.hot.clear();
        self.frozen = vec![true; self.flows.len()];
        self.cap_sorted.clear();
        self.cons_residual = vec![0.0; nc];
        self.cons_hist_idx = vec![0; nc];
        self.cons_live = vec![0; nc];
        self.cons_ev_pos = vec![Vec::new(); nc];
        self.cons_ev_cursor = vec![0; nc];
        self.cons_eval_round = vec![0; nc];
        self.cons_vkey_bits = vec![NO_KEY; nc];
        for k in 0..self.active.len() {
            self.activate(self.active[k]);
        }
    }

    /// Apply a fault to the running fabric: rescale the affected
    /// capacity constraints and rebuild the solver state, so the next
    /// event solves against the degraded capacities. A capacity pinned
    /// at zero freezes its member flows at rate 0 — the stall the
    /// monitor → replan recovery loop observes and routes around.
    /// Fault-free runs never call this, keeping them bit-identical.
    pub fn apply_fault(&mut self, fault: &faults::Fault) {
        self.faulted = true;
        match *fault {
            faults::Fault::LinkDown { link } => self.link_scale[link] = 0.0,
            faults::Fault::LinkUp { link } => self.link_scale[link] = 1.0,
            faults::Fault::RailDegraded { rail, factor } => {
                for l in faults::rail_links(self.sim.topo, rail) {
                    self.link_scale[l] = factor;
                }
            }
            faults::Fault::StragglerNode { node, inject_factor } => {
                for local in 0..self.sim.topo.gpus_per_node {
                    let g = self.sim.topo.gpu(node, local);
                    self.inject_scale[g] = inject_factor;
                }
                for l in faults::node_out_links(self.sim.topo, node) {
                    self.link_scale[l] = inject_factor;
                }
            }
        }
        self.rebuild();
    }

    /// Bookkeeping when flow `i` joins the active set: bump its
    /// constraints' active-member counts and insert it into the
    /// rate-cap order.
    fn activate(&mut self, i: usize) {
        for k in 0..self.flow_cons[i].len() {
            let ci = self.flow_cons[i][k];
            if self.active_count[ci] == 0 && !self.in_hot[ci] {
                self.in_hot[ci] = true;
                self.hot.push(ci);
            }
            self.active_count[ci] += 1;
        }
        let key = (self.rate_cap[i], i);
        let pos = self
            .cap_sorted
            .partition_point(|&j| (self.rate_cap[j], j) < key);
        self.cap_sorted.insert(pos, i);
    }

    /// Inverse of [`SimEngine::activate`] (flow finished or preempted).
    /// Constraints left without active members stay on the `hot` list
    /// and are pruned lazily by the next solve.
    fn deactivate(&mut self, i: usize) {
        for k in 0..self.flow_cons[i].len() {
            let ci = self.flow_cons[i][k];
            debug_assert!(self.active_count[ci] > 0, "active-count underflow on {ci}");
            self.active_count[ci] -= 1;
        }
        let key = (self.rate_cap[i], i);
        let pos = self
            .cap_sorted
            .partition_point(|&j| (self.rate_cap[j], j) < key);
        debug_assert_eq!(self.cap_sorted.get(pos), Some(&i), "cap order lost flow {i}");
        self.cap_sorted.remove(pos);
    }

    /// Lazily replay the exact eager subtraction sequence for
    /// constraint `ci`: water-level increments `history[hist_idx..upto]`
    /// interleaved with the recorded live-decrement events (positions
    /// ≤ `upto`), charging `residual -= δ · live` with exactly the same
    /// operations — in the same order — as the reference solver would
    /// have. Returns whether the constraint still has live members.
    fn replay(&mut self, ci: usize, upto: usize) -> bool {
        let mut idx = self.cons_hist_idx[ci] as usize;
        let mut live = self.cons_live[ci];
        let mut r = self.cons_residual[ci];
        let mut cur = self.cons_ev_cursor[ci] as usize;
        loop {
            let nxt = self.cons_ev_pos[ci].get(cur).map(|&p| p as usize);
            let stop = match nxt {
                Some(p) if p <= upto => p,
                _ => upto,
            };
            if live > 0 {
                let lf = live as f64;
                while idx < stop {
                    r -= self.history[idx] * lf;
                    idx += 1;
                }
            } else {
                idx = stop;
            }
            match nxt {
                Some(p) if p <= upto && p == stop => {
                    while cur < self.cons_ev_pos[ci].len()
                        && self.cons_ev_pos[ci][cur] as usize == stop
                    {
                        debug_assert!(live > 0, "double-freeze accounting on constraint {ci}");
                        live -= 1;
                        cur += 1;
                    }
                }
                _ => break,
            }
        }
        self.cons_hist_idx[ci] = idx as u32;
        self.cons_live[ci] = live;
        self.cons_residual[ci] = r;
        self.cons_ev_cursor[ci] = cur as u32;
        live > 0
    }

    /// Incremental water-filling solve: identical floating-point
    /// trajectory to [`FluidSim::max_min_rates`], with the per-event
    /// work cut three ways:
    ///
    /// * constraint liveness comes from the maintained active-member
    ///   counts instead of rescanning every membership list;
    /// * the per-flow-ceiling minimum is read off the rate-cap order
    ///   (`cap_sorted`) instead of scanning every flow — fl(x − level)
    ///   is monotone in x, so the first unfrozen entry is the minimum
    ///   and cap-frozen flows form a prefix;
    /// * per-constraint charging is **lazy**: a min-heap over
    ///   conservative headroom keys finds the binding constraints; only
    ///   those are evaluated exactly (replaying their charge history —
    ///   [`SimEngine::replay`]); all others just record the round in
    ///   `history` and are never touched. A constraint whose stale key
    ///   sits more than [`HEADROOM_SLACK`] above the round's minimum
    ///   provably cannot bind or saturate, because keys only ever
    ///   *under*-estimate headroom (live decrements raise it) and the
    ///   replayed FP drift is orders of magnitude below the slack.
    fn solve_incremental(&mut self) {
        use std::cmp::Reverse;
        // prune constraints that lost their last active member; seed
        // lazy state + heap keys (level = 0 ⇒ key = initial headroom)
        let mut heap_vec = std::mem::take(&mut self.heap_buf);
        heap_vec.clear();
        let mut k = 0;
        while k < self.hot.len() {
            let ci = self.hot[k];
            if self.active_count[ci] == 0 {
                self.in_hot[ci] = false;
                self.hot.swap_remove(k);
                continue;
            }
            self.cons_residual[ci] = self.cons_cap[ci];
            self.cons_hist_idx[ci] = 0;
            self.cons_live[ci] = self.active_count[ci];
            self.cons_ev_cursor[ci] = 0;
            self.cons_ev_pos[ci].clear();
            let h0 = self.cons_residual[ci] / self.cons_live[ci] as f64;
            self.cons_vkey_bits[ci] = h0.to_bits();
            heap_vec.push(Reverse((h0.to_bits(), ci as u32)));
            k += 1;
        }
        let mut heap = std::collections::BinaryHeap::from(heap_vec);
        for k in 0..self.active.len() {
            self.frozen[self.active[k]] = false;
        }
        self.history.clear();
        let mut level = 0.0f64;
        let mut n_unfrozen = self.active.len();
        // first not-yet-frozen entry of the rate-cap order; only ever
        // advances within a solve (freezing is permanent per solve)
        let mut cap_ptr = 0usize;
        let mut newly = std::mem::take(&mut self.newly_frozen);
        let mut evaluated = std::mem::take(&mut self.evaluated_buf);
        while n_unfrozen > 0 {
            self.round_counter += 1;
            let stamp = self.round_counter;
            while cap_ptr < self.cap_sorted.len() && self.frozen[self.cap_sorted[cap_ptr]] {
                cap_ptr += 1;
            }
            let mut cand = f64::INFINITY;
            if cap_ptr < self.cap_sorted.len() {
                cand = self.rate_cap[self.cap_sorted[cap_ptr]] - level;
            }
            // pop every constraint whose conservative headroom could be
            // the round minimum and evaluate it exactly
            evaluated.clear();
            while let Some(&Reverse((kb, ci32))) = heap.peek() {
                let ci = ci32 as usize;
                if self.cons_vkey_bits[ci] != kb || self.cons_eval_round[ci] == stamp {
                    heap.pop(); // stale or duplicate entry
                    continue;
                }
                if f64::from_bits(kb) - level > cand + HEADROOM_SLACK {
                    break;
                }
                heap.pop();
                let upto = self.history.len();
                if !self.replay(ci, upto) {
                    self.cons_vkey_bits[ci] = NO_KEY; // dead: drop entries
                    continue;
                }
                let h = self.cons_residual[ci] / self.cons_live[ci] as f64;
                self.cons_eval_round[ci] = stamp;
                self.cons_vkey_bits[ci] = (level + h).to_bits();
                evaluated.push(ci);
                if h < cand {
                    cand = h;
                }
            }
            let mut delta = cand;
            if !delta.is_finite() {
                // no binding constraint: everyone rides their own cap
                delta = 0.0;
            }
            let delta = delta.max(0.0);
            level += delta;
            self.history.push(delta);
            newly.clear();
            // flows at their cap: a prefix of the unfrozen subsequence
            // of the rate-cap order
            let mut p = cap_ptr;
            while p < self.cap_sorted.len() {
                let i = self.cap_sorted[p];
                if !self.frozen[i] {
                    if self.rate_cap[i] - level <= 1e-9 {
                        newly.push(i);
                    } else {
                        break;
                    }
                }
                p += 1;
            }
            // saturated constraints are necessarily among the evaluated
            // set (everything else sits ≥ SLACK above the minimum);
            // apply this round's charge to them and test exactly
            for e in 0..evaluated.len() {
                let ci = evaluated[e];
                let upto = self.history.len();
                self.replay(ci, upto);
                if self.cons_residual[ci] <= 1e-9 {
                    // poison the key: a saturated constraint is done
                    self.cons_vkey_bits[ci] = NO_KEY;
                    for &m in &self.constraints[ci].members {
                        if !self.frozen[m] {
                            newly.push(m);
                        }
                    }
                }
            }
            if newly.is_empty() {
                // numerical corner: freeze everything at current level
                for k in 0..self.active.len() {
                    let i = self.active[k];
                    if !self.frozen[i] {
                        self.rates[i] = level;
                        self.frozen[i] = true;
                    }
                }
                break;
            }
            newly.sort_unstable();
            newly.dedup();
            let pos = self.history.len() as u32;
            for idx in 0..newly.len() {
                let i = newly[idx];
                if self.frozen[i] {
                    continue;
                }
                self.rates[i] = level;
                self.frozen[i] = true;
                n_unfrozen -= 1;
                for k in 0..self.flow_cons[i].len() {
                    let ci = self.flow_cons[i][k];
                    self.cons_ev_pos[ci].push(pos);
                }
            }
            // surviving evaluated constraints re-enter the heap with
            // their refreshed conservative keys
            for e in 0..evaluated.len() {
                let ci = evaluated[e];
                if self.cons_vkey_bits[ci] != NO_KEY {
                    heap.push(Reverse((self.cons_vkey_bits[ci], ci as u32)));
                }
            }
        }
        self.newly_frozen = newly;
        evaluated.clear();
        self.evaluated_buf = evaluated;
        let mut buf = heap.into_vec();
        buf.clear();
        self.heap_buf = buf;
    }

    /// Preempt flow `i`: freeze it at the bytes moved so far and return
    /// the residual byte count for re-issue on other paths. Finished
    /// flows return 0. The flow's result records its preemption time as
    /// `finish_t` and only the bytes it actually carried.
    pub fn preempt(&mut self, i: usize) -> f64 {
        if !self.finish_t[i].is_nan() {
            return 0.0;
        }
        let residual = self.remaining[i].max(0.0);
        if let Some(pos) = self.active.iter().position(|&x| x == i) {
            self.active.swap_remove(pos);
            self.deactivate(i);
        } else if let Some(pos) = self.pending.iter().position(|&x| x == i) {
            self.pending.remove(pos);
        }
        self.finish_t[i] = self.t;
        self.remaining[i] = 0.0;
        self.preempted[i] = true;
        residual
    }

    /// Bucket this window's per-flow byte counters per link by
    /// (tag, src, dst) and reset them — the shared reduction behind
    /// both window drains, so their totals are bit-identical.
    fn window_attr(&mut self) -> WindowAttr {
        let mut per_link: Vec<BTreeMap<BlameKey, f64>> =
            vec![BTreeMap::new(); self.link_bytes.len()];
        for (i, f) in self.flows.iter().enumerate() {
            let w = self.win_flow[i];
            if w == 0.0 {
                continue;
            }
            let key = (f.tag, f.path.src, f.path.dst);
            for &h in &f.path.hops {
                *per_link[h].entry(key).or_insert(0.0) += w;
            }
        }
        for w in &mut self.win_flow {
            *w = 0.0;
        }
        reduce_blame(per_link)
    }

    /// Per-link bytes moved since the previous `take_window` call (the
    /// monitor's sampling window); resets the window counters.
    pub fn take_window(&mut self) -> Vec<f64> {
        self.window_attr().totals
    }

    /// [`SimEngine::take_window`] plus the per-link (tag, src, dst)
    /// blame decomposition; totals carry the identical bits.
    pub fn take_window_attr(&mut self) -> WindowAttr {
        self.window_attr()
    }

    /// Advance the event loop until `t_stop` (a replan epoch boundary)
    /// or until every flow completes, whichever comes first.
    pub fn advance_to(&mut self, t_stop: f64) {
        while !self.is_done() {
            // admit arrivals at the current time
            while let Some(&i) = self.pending.last() {
                if self.start_t[i] <= self.t + 1e-15 {
                    self.active.push(i);
                    self.pending.pop();
                    self.activate(i);
                } else {
                    break;
                }
            }
            if self.active.is_empty() {
                let next = self.start_t[*self.pending.last().unwrap()];
                if next > t_stop {
                    self.t = self.t.max(t_stop);
                    return;
                }
                self.t = next;
                continue;
            }
            self.events += 1;
            match self.solver {
                SolverKind::Incremental => self.solve_incremental(),
                SolverKind::Reference => self.sim.max_min_rates(
                    &self.constraints,
                    &self.flow_cons,
                    &self.rate_cap,
                    &self.active,
                    &mut self.rates,
                ),
            }
            // next event: earliest completion or next arrival
            let mut dt = f64::INFINITY;
            for &i in &self.active {
                if self.rates[i] > 0.0 {
                    dt = dt.min(self.remaining[i] / self.rates[i]);
                }
            }
            if let Some(&i) = self.pending.last() {
                dt = dt.min(self.start_t[i] - self.t);
            }
            if !dt.is_finite() {
                // A fault can pin every active flow at rate zero (all
                // paths cross dead links). With a finite epoch bound,
                // park there so the recovery loop can observe the
                // stall and reroute; with no bound the run genuinely
                // cannot make progress.
                assert!(
                    t_stop.is_finite(),
                    "stuck: no progress possible (all rates zero)"
                );
                if t_stop > self.t {
                    self.t = t_stop;
                }
                return;
            }
            // clamp at the epoch boundary
            let stopping = self.t + dt > t_stop;
            let dt = if stopping { (t_stop - self.t).max(0.0) } else { dt };
            // advance
            for &i in &self.active {
                let moved = self.rates[i] * dt;
                self.remaining[i] -= moved;
                self.moved[i] += moved;
                self.win_flow[i] += moved;
                for &h in &self.flows[i].path.hops {
                    self.link_bytes[h] += moved;
                }
            }
            self.t += dt;
            // retire completions (order-preserving, like `retain`)
            let t = self.t;
            let mut pos = 0;
            while pos < self.active.len() {
                let i = self.active[pos];
                if self.remaining[i] <= 1e-6 {
                    self.finish_t[i] = t;
                    self.active.remove(pos);
                    self.deactivate(i);
                } else {
                    pos += 1;
                }
            }
            if stopping {
                return;
            }
        }
        if t_stop.is_finite() && t_stop > self.t {
            self.t = t_stop;
        }
    }

    /// Run every remaining event (no epoch bound).
    pub fn run_to_completion(&mut self) {
        self.advance_to(f64::INFINITY);
    }

    /// Snapshot the outcome. `FlowResult::bytes` is the bytes a flow
    /// actually carried, so preempted flows and their re-issued
    /// residuals sum to the original payload without double counting.
    pub fn result(&self) -> SimResult {
        let makespan = self
            .finish_t
            .iter()
            .cloned()
            .filter(|t| !t.is_nan())
            .fold(0.0, f64::max);
        SimResult {
            flows: (0..self.flows.len())
                .map(|i| FlowResult {
                    start_t: self.start_t[i],
                    finish_t: self.finish_t[i],
                    bytes: if self.preempted[i] {
                        self.moved[i]
                    } else {
                        self.flows[i].bytes
                    },
                })
                .collect(),
            link_bytes: self.link_bytes.clone(),
            makespan,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::path::candidates;
    use crate::topology::Topology;

    const MB: f64 = 1024.0 * 1024.0;

    fn sim(topo: &Topology) -> FluidSim<'_> {
        FluidSim::new(topo, FabricParams::default())
    }

    /// Fig 6a anchor: direct NVLink large-message ≈ 120 GB/s.
    #[test]
    fn direct_nvlink_saturates() {
        let t = Topology::paper();
        let s = sim(&t);
        let path = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[Flow::new(path, 1024.0 * MB)]);
        let bw = r.aggregate_gbps();
        assert!(bw > 115.0 && bw <= 120.0, "bw={bw}");
    }

    /// Fig 6a anchor: direct + 1 relay ⇒ ≈213 GB/s; +2 relays ⇒ ≈278 GB/s.
    #[test]
    fn multipath_intra_matches_paper_anchors() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 1, true);
        let big = 512.0 * MB;
        // direct + via-2, bytes split ∝ the achievable per-path rates
        // (120 : ρ·120) so the two flows drain together.
        let r2 = s.run(&[
            Flow::new(cands[0].clone(), big),
            Flow::new(cands[1].clone(), big * 0.776),
        ]);
        let bw2 = (big + big * 0.776) / r2.makespan / 1e9;
        assert!((bw2 - 213.1).abs() < 8.0, "2-path bw={bw2}");
        // direct + via-2 + via-3: the source injection cap (278.2)
        // binds and max-min equalizes the three flows near 92.7 GB/s
        // each, so an equal byte split drains together. The AGGREGATE
        // is the paper's 278.2 anchor.
        let r3 = s.run(&[
            Flow::new(cands[0].clone(), big),
            Flow::new(cands[1].clone(), big),
            Flow::new(cands[2].clone(), big),
        ]);
        let bw3 = (3.0 * big) / r3.makespan / 1e9;
        assert!((bw3 - 278.2).abs() < 10.0, "3-path bw={bw3}");
    }

    /// Fig 6b anchor: 1 rail ≈45.1, 4 rails ≈170 GB/s aggregate.
    #[test]
    fn multirail_inter_matches_paper_anchors() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 4, true); // gpu0 → gpu4 (node1, rail0)
        let big = 512.0 * MB;
        let r1 = s.run(&[Flow::new(cands[0].clone(), big)]);
        let bw1 = r1.aggregate_gbps();
        assert!((bw1 - 45.1).abs() < 2.0, "1 rail bw={bw1}");
        let flows: Vec<Flow> =
            cands.iter().map(|p| Flow::new(p.clone(), big)).collect();
        let r4 = s.run(&flows);
        let bw4 = (4.0 * big) / r4.makespan / 1e9;
        assert!((bw4 - 170.0).abs() < 6.0, "4 rails bw={bw4}");
    }

    #[test]
    fn link_byte_conservation() {
        let t = Topology::paper();
        let s = sim(&t);
        let path = candidates(&t, 0, 1, true).remove(1); // 2-hop
        let bytes = 64.0 * MB;
        let r = s.run(&[Flow::new(path, bytes)]);
        // each of the 2 hops carries the full payload
        let total: f64 = r.link_bytes.iter().sum();
        assert!((total - 2.0 * bytes).abs() < 1.0, "total={total}");
    }

    #[test]
    fn fair_share_two_flows_one_link() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        // two equal flows over the same NVLink edge finish together at
        // half rate each.
        let r = s.run(&[
            Flow::new(p.clone(), 256.0 * MB),
            Flow::new(p.clone(), 256.0 * MB),
        ]);
        let d = (r.flows[0].finish_t - r.flows[1].finish_t).abs();
        assert!(d < 1e-6, "finish skew {d}");
        let bw = r.aggregate_gbps();
        assert!(bw <= 120.0 + 1e-6 && bw > 110.0, "bw={bw}");
    }

    #[test]
    fn staggered_arrivals_progress() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[
            Flow::new(p.clone(), 64.0 * MB),
            Flow::new(p.clone(), 64.0 * MB).at(0.01),
        ]);
        assert!(r.flows[1].finish_t > r.flows[0].finish_t);
        assert!(r.makespan >= 0.01);
    }

    #[test]
    fn small_message_latency_dominated() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let r = s.run(&[Flow::new(p, 64.0 * 1024.0)]); // 64 KB
        let bw = r.aggregate_gbps();
        // far from peak: overhead + unsaturated curve
        assert!(bw < 10.0, "bw={bw}");
    }

    /// With no preemptions/additions, engine == closed-form run,
    /// bit for bit (run() IS the engine, but guard the equivalence).
    #[test]
    fn engine_matches_run_bit_identically() {
        let t = Topology::paper();
        let s = sim(&t);
        let cands = candidates(&t, 0, 1, true);
        let flows = vec![
            Flow::new(cands[0].clone(), 96.0 * MB),
            Flow::new(cands[1].clone(), 64.0 * MB).at(0.0005),
            Flow::new(cands[2].clone(), 32.0 * MB).at(0.001),
        ];
        let r1 = s.run(&flows);
        let mut e = SimEngine::new(&t, FabricParams::default(), &flows);
        e.run_to_completion();
        let r2 = e.result();
        assert_eq!(r1.makespan.to_bits(), r2.makespan.to_bits());
        for (a, b) in r1.flows.iter().zip(&r2.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
        }
        assert_eq!(r1.link_bytes, r2.link_bytes);
    }

    /// Epoch-sliced advancement only splits integration intervals; the
    /// trajectory stays the same up to float noise.
    #[test]
    fn engine_epoch_slicing_preserves_trajectory() {
        let t = Topology::paper();
        let s = sim(&t);
        let p = candidates(&t, 0, 1, false).remove(0);
        let flows = vec![
            Flow::new(p.clone(), 256.0 * MB),
            Flow::new(p.clone(), 128.0 * MB).at(0.002),
        ];
        let whole = s.run(&flows);
        let mut e = SimEngine::new(&t, FabricParams::default(), &flows);
        let mut epoch = 0.0005;
        while !e.is_done() {
            e.advance_to(epoch);
            epoch += 0.0005;
        }
        let sliced = e.result();
        assert!((whole.makespan - sliced.makespan).abs() < 1e-9);
        for (a, b) in whole.link_bytes.iter().zip(&sliced.link_bytes) {
            assert!((a - b).abs() < 1.0, "link bytes drifted: {a} vs {b}");
        }
    }

    /// Preempting a flow and re-issuing its residual on another path
    /// conserves payload bytes and still finishes everything.
    #[test]
    fn engine_preempt_and_reissue_conserves_bytes() {
        let t = Topology::paper();
        let cands = candidates(&t, 0, 1, true);
        let bytes = 256.0 * MB;
        let initial = [Flow::new(cands[0].clone(), bytes)];
        let mut e = SimEngine::new(&t, FabricParams::default(), &initial);
        // run a third of the nominal drain, then reroute the rest
        e.advance_to(0.0008);
        assert!(!e.is_done());
        let residual = e.preempt(0);
        assert!(residual > 0.0 && residual < bytes);
        let moved = e.moved_bytes(0);
        assert!((moved + residual - bytes).abs() < 1.0);
        e.add_flows(&[Flow::new(cands[1].clone(), residual).at(e.now())]);
        e.run_to_completion();
        let r = e.result();
        let delivered: f64 = r.flows.iter().map(|f| f.bytes).sum();
        assert!((delivered - bytes).abs() < 1.0, "delivered {delivered}");
        assert!(r.flows[1].finish_t > r.flows[0].finish_t);
        // the relay path actually carried the residual
        for &h in &cands[1].hops {
            assert!((r.link_bytes[h] - residual).abs() < 1.0);
        }
    }

    /// Window sampling partitions the cumulative per-link byte counts.
    #[test]
    fn engine_windows_partition_link_bytes() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0);
        let mut e =
            SimEngine::new(&t, FabricParams::default(), &[Flow::new(p, 64.0 * MB)]);
        let mut summed = vec![0.0; t.links.len()];
        let mut epoch = 0.0004;
        while !e.is_done() {
            e.advance_to(epoch);
            for (s, w) in summed.iter_mut().zip(e.take_window()) {
                *s += w;
            }
            epoch += 0.0004;
        }
        let r = e.result();
        for (i, (&s, &tot)) in summed.iter().zip(&r.link_bytes).enumerate() {
            assert!((s - tot).abs() < 1.0, "link {i}: windows {s} vs total {tot}");
        }
    }

    /// The incremental solver retraces the reference solver's exact
    /// trajectory — including across an epoch-sliced run with a
    /// mid-flight preemption and re-issued residuals.
    #[test]
    fn incremental_solver_matches_reference_bitwise() {
        let t = Topology::paper();
        let cands01 = candidates(&t, 0, 1, true);
        let cands14 = candidates(&t, 1, 4, true);
        let flows = vec![
            Flow::new(cands01[0].clone(), 96.0 * MB),
            Flow::new(cands01[1].clone(), 64.0 * MB).at(0.0005),
            Flow::new(cands14[0].clone(), 48.0 * MB),
            Flow::new(cands14[1].clone(), 32.0 * MB).at(0.001),
        ];
        let drive = |solver: SolverKind| {
            let mut e = SimEngine::new(&t, FabricParams::default(), &flows);
            e.set_solver(solver);
            let mut epoch = 0.0004;
            let mut preempted = false;
            while !e.is_done() {
                e.advance_to(epoch);
                epoch += 0.0004;
                if !preempted && e.is_live(0) && e.now() > 0.0006 {
                    let residual = e.preempt(0);
                    e.add_flows(&[Flow::new(cands01[2].clone(), residual).at(e.now())]);
                    preempted = true;
                }
            }
            assert!(preempted, "scenario never exercised preempt/add_flows");
            (e.result(), e.events())
        };
        let (ri, ei) = drive(SolverKind::Incremental);
        let (rr, er) = drive(SolverKind::Reference);
        assert_eq!(ei, er, "event counts diverged");
        assert_eq!(ri.makespan.to_bits(), rr.makespan.to_bits());
        for (a, b) in ri.flows.iter().zip(&rr.flows) {
            assert_eq!(a.finish_t.to_bits(), b.finish_t.to_bits());
            assert_eq!(a.bytes.to_bits(), b.bytes.to_bits());
        }
        assert_eq!(ri.link_bytes, rr.link_bytes);
    }

    /// A dead link pins its flow at rate zero (the engine parks at
    /// epoch bounds instead of asserting); LinkUp restores service and
    /// the payload still lands in full.
    #[test]
    fn fault_link_flap_stalls_then_recovers() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 4, false).remove(0); // rail 0, single hop
        let bytes = 64.0 * MB;
        let mut e =
            SimEngine::new(&t, FabricParams::default(), &[Flow::new(p.clone(), bytes)]);
        e.advance_to(0.0002);
        let before = e.moved_bytes(0);
        assert!(before > 0.0);
        e.apply_fault(&faults::Fault::LinkDown { link: p.hops[0] });
        e.advance_to(0.0010);
        assert!((e.moved_bytes(0) - before).abs() < 1.0, "dead link moved bytes");
        assert!(!e.is_done());
        e.apply_fault(&faults::Fault::LinkUp { link: p.hops[0] });
        e.run_to_completion();
        let r = e.result();
        assert!((r.flows[0].bytes - bytes).abs() < 1.0);
        assert!(r.makespan >= 0.0010);
    }

    /// A straggler node throttles the capacity of its GPUs' out-links:
    /// the same transfer takes a multiple of the healthy makespan.
    #[test]
    fn fault_straggler_throttles_source_node() {
        let t = Topology::paper();
        let p = candidates(&t, 0, 1, false).remove(0);
        let bytes = 128.0 * MB;
        let mut healthy =
            SimEngine::new(&t, FabricParams::default(), &[Flow::new(p.clone(), bytes)]);
        healthy.run_to_completion();
        let mut slow =
            SimEngine::new(&t, FabricParams::default(), &[Flow::new(p, bytes)]);
        slow.apply_fault(&faults::Fault::StragglerNode {
            node: 0,
            inject_factor: 0.25,
        });
        slow.run_to_completion();
        assert!(
            slow.result().makespan > 1.5 * healthy.result().makespan,
            "straggler did not slow the source"
        );
    }

    #[test]
    fn node_net_cap_binds_aggregate() {
        let t = Topology::paper();
        let s = sim(&t);
        // all 4 GPUs of node 0 send to their rail peers simultaneously
        let mut flows = Vec::new();
        for g in 0..4 {
            let p = candidates(&t, g, g + 4, false).remove(0);
            flows.push(Flow::new(p, 512.0 * MB));
        }
        let r = s.run(&flows);
        let agg = (4.0 * 512.0 * MB) / r.makespan / 1e9;
        assert!(agg <= 170.0 + 1.0, "agg={agg}");
        assert!(agg > 160.0, "agg={agg}");
    }
}
