//! Deterministic fault injection (DESIGN.md §13).
//!
//! Real fabrics do not only skew — they *degrade*: links flap, rails
//! get throttled, endpoints straggle. A [`FaultSchedule`] is a seeded,
//! time-sorted list of [`FaultEvent`]s that both fabric backends honor
//! through [`FabricBackend::apply_fault`](super::FabricBackend):
//!
//! * [`Fault::LinkDown`] / [`Fault::LinkUp`] — a link dies and later
//!   recovers (a flap). The fluid engine pins the link's capacity
//!   constraint at zero (members freeze at rate 0); the packet engine
//!   freezes the link's queue (no new cell enters service; cells
//!   already queued wait; the coordinator's recovery loop preempts the
//!   affected flows, which aborts their in-fabric cells and returns the
//!   undelivered chunks to the residual pool).
//! * [`Fault::RailDegraded`] — every link of one rail plane keeps
//!   working at `factor ×` capacity (a throttled NIC/switch ASIC).
//!   `factor = 1.0` restores the rail.
//! * [`Fault::StragglerNode`] — one node turns slow at *sourcing*
//!   bytes: the injection cap of its GPUs and the capacity of every
//!   link leaving one of its GPUs are scaled by `inject_factor` (HBM
//!   read + copy kernels + NIC DMA all share the straggling clock).
//!   `inject_factor = 1.0` restores the node.
//!
//! The schedule is pure data: identical inputs produce byte-identical
//! event lists, and [`FaultSchedule::trace`] renders the canonical
//! textual form the determinism properties compare. An **empty**
//! schedule injects nothing and leaves every run bit-identical to a
//! fault-free build — the recovery machinery is only reachable when a
//! fault actually fires.
//!
//! On the packet engine, `apply_fault` mutates capacity scales and
//! re-kicks the affected service loops by *scheduling* events — it
//! never touches queue internals — so faults land identically in
//! whichever scheduler is active (wheel or heap; pinned by
//! `prop_wheel_matches_heap_under_faults`) and, under the partitioned
//! loop, are broadcast to every live component and replayed onto
//! components created later (DESIGN.md §14).
//!
//! [`scenario_schedule`] generates the four named scenarios the
//! `nimble faults` experiment flies (flap / degrade / straggler /
//! mixed) from a seed plus an optional per-link load profile: the
//! flap targets the *hottest* inter-node link (the worst case for a
//! static plan), the degrade targets that link's rail, the straggler
//! the node sourcing it.

use crate::topology::{LinkKind, Topology};
use crate::util::rng::Rng;

/// One fault applied to the running fabric at a scheduled time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Link `link` stops serving (capacity → 0) until a `LinkUp`.
    LinkDown { link: usize },
    /// Link `link` recovers to full capacity.
    LinkUp { link: usize },
    /// Every link of rail plane `rail` (NIC edges, leaf and spine
    /// links on tiered fabrics) runs at `factor ×` capacity.
    /// `factor = 1.0` restores the rail.
    RailDegraded { rail: usize, factor: f64 },
    /// Node `node` sources bytes at `inject_factor ×` speed: its GPUs'
    /// injection caps and the capacity of every link leaving one of
    /// its GPUs are scaled. `inject_factor = 1.0` restores.
    StragglerNode { node: usize, inject_factor: f64 },
}

/// A [`Fault`] stamped with the virtual time (seconds) it fires at.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub t_s: f64,
    pub fault: Fault,
}

/// A time-sorted fault event list with a replay cursor.
///
/// The default (empty) schedule is inert: nothing consumes it and runs
/// stay bit-identical to pre-fault builds.
#[derive(Clone, Debug, Default)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
    cursor: usize,
}

impl FaultSchedule {
    /// Build from an event list (stably sorted by fire time).
    pub fn new(mut events: Vec<FaultEvent>) -> FaultSchedule {
        events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).expect("NaN fault time"));
        FaultSchedule { events, cursor: 0 }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// All events (fire order), regardless of the cursor.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Events due at or before `t` that have not been taken yet;
    /// advances the cursor past them.
    pub fn due(&mut self, t: f64) -> &[FaultEvent] {
        let start = self.cursor;
        let mut end = start;
        while end < self.events.len() && self.events[end].t_s <= t {
            end += 1;
        }
        self.cursor = end;
        &self.events[start..end]
    }

    /// Fire time of the next untaken event.
    pub fn peek_next_t(&self) -> Option<f64> {
        self.events.get(self.cursor).map(|e| e.t_s)
    }

    /// Whether every event has been taken.
    pub fn drained(&self) -> bool {
        self.cursor >= self.events.len()
    }

    /// Rewind the cursor (replay the schedule from the start).
    pub fn reset(&mut self) {
        self.cursor = 0;
    }

    /// Canonical textual form: one line per event. Two schedules built
    /// from identical inputs render byte-identical traces (the
    /// determinism property in `tests/fault_props.rs`).
    pub fn trace(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            let line = match e.fault {
                Fault::LinkDown { link } => format!("t={:.9} down link={link}\n", e.t_s),
                Fault::LinkUp { link } => format!("t={:.9} up link={link}\n", e.t_s),
                Fault::RailDegraded { rail, factor } => {
                    format!("t={:.9} degrade rail={rail} factor={factor}\n", e.t_s)
                }
                Fault::StragglerNode { node, inject_factor } => {
                    format!("t={:.9} straggler node={node} factor={inject_factor}\n", e.t_s)
                }
            };
            out.push_str(&line);
        }
        out
    }

    /// Fail-closed validation against a topology: every referenced
    /// link/rail/node must exist, factors must lie in (0, 1] (NaN
    /// rejected), fire times must be finite and non-negative.
    pub fn validate(&self, topo: &Topology) -> Result<(), String> {
        let in_unit = |f: f64| f > 0.0 && f <= 1.0; // NaN fails both
        for e in &self.events {
            if !e.t_s.is_finite() || e.t_s < 0.0 {
                return Err(format!("fault time {} not finite/non-negative", e.t_s));
            }
            match e.fault {
                Fault::LinkDown { link } | Fault::LinkUp { link } => {
                    if link >= topo.links.len() {
                        return Err(format!(
                            "fault references link {link}, topology has {}",
                            topo.links.len()
                        ));
                    }
                }
                Fault::RailDegraded { rail, factor } => {
                    if rail >= topo.nics_per_node {
                        return Err(format!(
                            "fault references rail {rail}, topology has {}",
                            topo.nics_per_node
                        ));
                    }
                    if !in_unit(factor) {
                        return Err(format!("degrade factor {factor} outside (0, 1]"));
                    }
                }
                Fault::StragglerNode { node, inject_factor } => {
                    if node >= topo.nodes {
                        return Err(format!(
                            "fault references node {node}, topology has {}",
                            topo.nodes
                        ));
                    }
                    if !in_unit(inject_factor) {
                        return Err(format!(
                            "straggler factor {inject_factor} outside (0, 1]"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// All links belonging to rail plane `rail`: flat NIC edges on that
/// rail, cross-rail edges touching it, and (tiered) its leaf and spine
/// links. NVLink edges belong to no rail.
pub fn rail_links(topo: &Topology, rail: usize) -> Vec<usize> {
    topo.links
        .iter()
        .enumerate()
        .filter(|(_, l)| match l.kind {
            LinkKind::NvLink => false,
            LinkKind::Rail { rail: r }
            | LinkKind::LeafUp { rail: r }
            | LinkKind::LeafDown { rail: r }
            | LinkKind::SpineUp { rail: r, .. }
            | LinkKind::SpineDown { rail: r, .. } => r == rail,
            LinkKind::CrossRail { src_rail, dst_rail } => {
                src_rail == rail || dst_rail == rail
            }
        })
        .map(|(i, _)| i)
        .collect()
}

/// All links leaving a GPU of `node` (the straggler's slowed egress
/// set: NVLink out-edges plus NIC/leaf uplinks sourced on the node).
pub fn node_out_links(topo: &Topology, node: usize) -> Vec<usize> {
    topo.links
        .iter()
        .enumerate()
        .filter(|(_, l)| !topo.is_switch(l.src) && topo.node_of(l.src) == node)
        .map(|(i, _)| i)
        .collect()
}

/// Fold one fault into a per-link capacity-scale vector — the
/// planner-facing mirror of what the backends apply internally
/// (`scale[l] == 0.0` dead, `1.0` healthy). The coordinator maintains
/// this vector across events and hands it to
/// [`Planner::set_link_health`](crate::planner::Planner::set_link_health).
pub fn apply_to_scale(scale: &mut [f64], topo: &Topology, fault: &Fault) {
    match *fault {
        Fault::LinkDown { link } => scale[link] = 0.0,
        Fault::LinkUp { link } => scale[link] = 1.0,
        Fault::RailDegraded { rail, factor } => {
            for l in rail_links(topo, rail) {
                scale[l] = factor;
            }
        }
        Fault::StragglerNode { node, inject_factor } => {
            for l in node_out_links(topo, node) {
                scale[l] = inject_factor;
            }
        }
    }
}

/// The named fault scenarios `nimble faults` flies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// One link dies at `t0` and recovers one flap period later.
    Flap,
    /// One rail plane drops to `degrade_factor ×` capacity (no restore:
    /// recovery must come from re-routing, not from the fabric healing).
    Degrade,
    /// One node sources bytes at `straggler_factor ×` speed.
    Straggler,
    /// Flap + degrade + straggler, staggered.
    Mixed,
}

impl Scenario {
    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "flap" => Some(Scenario::Flap),
            "degrade" => Some(Scenario::Degrade),
            "straggler" => Some(Scenario::Straggler),
            "mixed" => Some(Scenario::Mixed),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Scenario::Flap => "flap",
            Scenario::Degrade => "degrade",
            Scenario::Straggler => "straggler",
            Scenario::Mixed => "mixed",
        }
    }

    pub fn all() -> [Scenario; 4] {
        [Scenario::Flap, Scenario::Degrade, Scenario::Straggler, Scenario::Mixed]
    }
}

/// Timing/intensity knobs for [`scenario_schedule`] (the validated
/// `[faults]` config section maps onto this).
#[derive(Clone, Copy, Debug)]
pub struct ScenarioParams {
    /// Seed for the fallback target picks (used only when no load
    /// profile identifies a hottest link).
    pub seed: u64,
    /// When the first fault fires (virtual seconds).
    pub t0_s: f64,
    /// Down time of a flap (LinkUp fires at `t0_s + flap_period_s`).
    pub flap_period_s: f64,
    /// Capacity factor of a degraded rail, in (0, 1].
    pub degrade_factor: f64,
    /// Sourcing-speed factor of a straggler node, in (0, 1].
    pub straggler_factor: f64,
}

impl Default for ScenarioParams {
    fn default() -> Self {
        ScenarioParams {
            seed: 0xFA17_5EED,
            t0_s: 1.0e-3,
            flap_period_s: 2.0e-3,
            degrade_factor: 0.25,
            straggler_factor: 0.25,
        }
    }
}

/// Resolved `[faults]` config section (see `configs/paper.toml`): a
/// named scenario plus its timing/intensity knobs. `scenario = None`
/// (TOML `"none"`) is the inert default — no schedule is built and
/// every run stays bit-identical to a fault-free build.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultsCfg {
    pub scenario: Option<Scenario>,
    pub params: ScenarioParams,
}

/// The hottest non-NVLink link under `link_load` (ties → lowest id);
/// seeded uniform pick among eligible links when no load profile is
/// given or all loads are zero.
fn hottest_fabric_link(topo: &Topology, link_load: Option<&[f64]>, rng: &mut Rng) -> usize {
    let eligible: Vec<usize> = topo
        .links
        .iter()
        .enumerate()
        .filter(|(_, l)| !matches!(l.kind, LinkKind::NvLink))
        .map(|(i, _)| i)
        .collect();
    assert!(!eligible.is_empty(), "topology has no fabric links");
    if let Some(load) = link_load {
        let mut best = eligible[0];
        let mut best_load = 0.0f64;
        for &l in &eligible {
            if load[l] > best_load {
                best_load = load[l];
                best = l;
            }
        }
        if best_load > 0.0 {
            return best;
        }
    }
    *rng.choose(&eligible)
}

/// Rail plane of a fabric link (`None` for NVLink edges).
fn rail_of(topo: &Topology, link: usize) -> Option<usize> {
    match topo.link(link).kind {
        LinkKind::NvLink => None,
        LinkKind::Rail { rail }
        | LinkKind::LeafUp { rail }
        | LinkKind::LeafDown { rail }
        | LinkKind::SpineUp { rail, .. }
        | LinkKind::SpineDown { rail, .. } => Some(rail),
        LinkKind::CrossRail { src_rail, .. } => Some(src_rail),
    }
}

/// Node sourcing a fabric link: the source vertex's node when it is a
/// GPU, else (switch-sourced links) a seeded pick.
fn source_node(topo: &Topology, link: usize, rng: &mut Rng) -> usize {
    let src = topo.link(link).src;
    if topo.is_switch(src) {
        rng.range_usize(0, topo.nodes)
    } else {
        topo.node_of(src)
    }
}

/// Generate the event list for a named scenario. Deterministic in
/// `(topo, scenario, params, link_load)`; the targets chase the
/// hottest loaded link so the fault hits where a static plan hurts
/// most. Every generated schedule validates against `topo` and leaves
/// the fabric able to finish (flaps restore, factors stay > 0).
pub fn scenario_schedule(
    topo: &Topology,
    scenario: Scenario,
    params: &ScenarioParams,
    link_load: Option<&[f64]>,
) -> FaultSchedule {
    let mut rng = Rng::new(params.seed);
    let hot = hottest_fabric_link(topo, link_load, &mut rng);
    let t0 = params.t0_s;
    let period = params.flap_period_s;
    let mut events = Vec::new();
    let flap = |events: &mut Vec<FaultEvent>, at: f64| {
        events.push(FaultEvent { t_s: at, fault: Fault::LinkDown { link: hot } });
        events.push(FaultEvent { t_s: at + period, fault: Fault::LinkUp { link: hot } });
    };
    let degrade = |events: &mut Vec<FaultEvent>, at: f64, rng: &mut Rng| {
        let rail = rail_of(topo, hot)
            .unwrap_or_else(|| rng.range_usize(0, topo.nics_per_node));
        events.push(FaultEvent {
            t_s: at,
            fault: Fault::RailDegraded { rail, factor: params.degrade_factor },
        });
    };
    let straggle = |events: &mut Vec<FaultEvent>, at: f64, rng: &mut Rng| {
        let node = source_node(topo, hot, rng);
        events.push(FaultEvent {
            t_s: at,
            fault: Fault::StragglerNode { node, inject_factor: params.straggler_factor },
        });
    };
    match scenario {
        Scenario::Flap => flap(&mut events, t0),
        Scenario::Degrade => degrade(&mut events, t0, &mut rng),
        Scenario::Straggler => straggle(&mut events, t0, &mut rng),
        Scenario::Mixed => {
            flap(&mut events, t0);
            degrade(&mut events, t0 + 0.5 * period, &mut rng);
            straggle(&mut events, t0 + period, &mut rng);
        }
    }
    let sched = FaultSchedule::new(events);
    debug_assert!(sched.validate(topo).is_ok());
    sched
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_schedule_is_inert() {
        let mut s = FaultSchedule::default();
        assert!(s.is_empty());
        assert_eq!(s.due(1.0), &[]);
        assert!(s.drained());
        assert_eq!(s.trace(), "");
    }

    #[test]
    fn due_advances_cursor_in_time_order() {
        let mut s = FaultSchedule::new(vec![
            FaultEvent { t_s: 2.0e-3, fault: Fault::LinkUp { link: 0 } },
            FaultEvent { t_s: 1.0e-3, fault: Fault::LinkDown { link: 0 } },
        ]);
        assert_eq!(s.peek_next_t(), Some(1.0e-3));
        let d = s.due(1.5e-3);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].fault, Fault::LinkDown { link: 0 });
        let d = s.due(5.0e-3);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].fault, Fault::LinkUp { link: 0 });
        assert!(s.drained());
        s.reset();
        assert_eq!(s.due(5.0e-3).len(), 2);
    }

    #[test]
    fn validation_rejects_bad_references_and_factors() {
        let t = Topology::paper();
        let bad_link = FaultSchedule::new(vec![FaultEvent {
            t_s: 0.0,
            fault: Fault::LinkDown { link: t.links.len() },
        }]);
        assert!(bad_link.validate(&t).is_err());
        let bad_rail = FaultSchedule::new(vec![FaultEvent {
            t_s: 0.0,
            fault: Fault::RailDegraded { rail: t.nics_per_node, factor: 0.5 },
        }]);
        assert!(bad_rail.validate(&t).is_err());
        let bad_factor = FaultSchedule::new(vec![FaultEvent {
            t_s: 0.0,
            fault: Fault::RailDegraded { rail: 0, factor: 0.0 },
        }]);
        assert!(bad_factor.validate(&t).is_err());
        let nan_factor = FaultSchedule::new(vec![FaultEvent {
            t_s: 0.0,
            fault: Fault::StragglerNode { node: 0, inject_factor: f64::NAN },
        }]);
        assert!(nan_factor.validate(&t).is_err());
        let bad_node = FaultSchedule::new(vec![FaultEvent {
            t_s: 0.0,
            fault: Fault::StragglerNode { node: t.nodes, inject_factor: 0.5 },
        }]);
        assert!(bad_node.validate(&t).is_err());
    }

    #[test]
    fn scenarios_are_deterministic_and_valid() {
        let t = Topology::fat_tree(8, 2.0);
        let p = ScenarioParams::default();
        for sc in Scenario::all() {
            let a = scenario_schedule(&t, sc, &p, None);
            let b = scenario_schedule(&t, sc, &p, None);
            assert_eq!(a.trace(), b.trace(), "{} not deterministic", sc.label());
            assert!(a.validate(&t).is_ok());
            assert!(!a.is_empty());
        }
    }

    #[test]
    fn flap_targets_hottest_loaded_link() {
        let t = Topology::paper();
        let mut load = vec![0.0; t.links.len()];
        let hot = rail_links(&t, 2)[0];
        load[hot] = 7.0e9;
        let s = scenario_schedule(
            &t,
            Scenario::Flap,
            &ScenarioParams::default(),
            Some(&load),
        );
        assert_eq!(s.events()[0].fault, Fault::LinkDown { link: hot });
        assert_eq!(s.events()[1].fault, Fault::LinkUp { link: hot });
        assert!(s.events()[1].t_s > s.events()[0].t_s);
    }

    #[test]
    fn rail_and_node_link_sets_cover_expectations() {
        let t = Topology::paper();
        // flat: per rail, one edge per direction per node pair + the
        // cross-rail edges touching the rail
        let r0 = rail_links(&t, 0);
        assert!(!r0.is_empty());
        for &l in &r0 {
            assert!(!matches!(t.link(l).kind, LinkKind::NvLink));
        }
        let out = node_out_links(&t, 0);
        for &l in &out {
            assert!(!t.is_switch(t.link(l).src));
            assert_eq!(t.node_of(t.link(l).src), 0);
        }
        // tiered: rail links include leaf + spine planes
        let ft = Topology::fat_tree(8, 2.0);
        let fr = rail_links(&ft, 1);
        let kinds: Vec<_> = fr.iter().map(|&l| ft.link(l).kind).collect();
        assert!(kinds.iter().any(|k| matches!(k, LinkKind::LeafUp { .. })));
        assert!(kinds.iter().any(|k| matches!(k, LinkKind::SpineUp { .. })));
    }
}
