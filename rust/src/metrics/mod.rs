//! Communication run reports: latency summaries, tail-latency /
//! queue-depth reports from the packet backend, link utilization,
//! imbalance metrics, and fixed-width table rendering used by the
//! experiment drivers and benches.

use crate::fabric::fluid::SimResult;
use crate::fabric::TailStats;
use crate::topology::Topology;
use crate::util::stats::{jain_index, Summary};

/// Outcome of one communication round under some engine.
#[derive(Clone, Debug)]
pub struct CommReport {
    pub engine: String,
    /// Wall-clock (virtual seconds) until the last byte landed.
    pub makespan_s: f64,
    /// Per-demand completion latency (seconds, from issue).
    pub latencies_s: Vec<f64>,
    /// Total bytes moved (payload, not counting multi-hop re-sends).
    pub payload_bytes: f64,
    /// Jain fairness index over busy-link utilization.
    pub link_fairness: f64,
    /// Highest per-link utilization (0..1) over the run.
    pub peak_link_util: f64,
    /// Number of distinct links that carried traffic.
    pub links_used: usize,
}

impl CommReport {
    pub fn from_sim(engine: &str, topo: &Topology, sim: &SimResult, payload: f64) -> Self {
        let util = sim.link_utilization(topo);
        let utils: Vec<f64> = util.iter().map(|&(_, u)| u).collect();
        CommReport {
            engine: engine.to_string(),
            makespan_s: sim.makespan,
            latencies_s: sim.flows.iter().map(|f| f.finish_t).collect(),
            payload_bytes: payload,
            link_fairness: if utils.is_empty() { 1.0 } else { jain_index(&utils) },
            peak_link_util: utils.iter().cloned().fold(0.0, f64::max),
            links_used: utils.len(),
        }
    }

    /// Effective goodput in GB/s.
    pub fn goodput_gbps(&self) -> f64 {
        self.payload_bytes / self.makespan_s.max(1e-12) / 1e9
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.latencies_s)
    }
}

/// Tail-latency and queue-depth report reduced from the packet
/// backend's bounded streaming histograms ([`TailStats`]) with
/// **nearest-rank** bucket quantiles
/// ([`crate::util::hist::LatencyHist::quantile_ns`]) — every reported
/// figure is the lower boundary of the bucket holding a latency some
/// chunk actually saw (within one bucket width, ≤3.2%, of the exact
/// nearest-rank sample; the max is tracked exactly). Latencies in
/// microseconds.
#[derive(Clone, Debug)]
pub struct TailReport {
    /// Chunks delivered end-to-end.
    pub chunks: u64,
    /// Sojourn (issue → delivery) percentiles.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Worst sojourn observed.
    pub max_us: f64,
    /// p99 of the pure transit component (first-queue entry →
    /// delivery): the congestion signal with source-side backlog
    /// excluded.
    pub transit_p99_us: f64,
    /// Deepest link queue observed anywhere, in bytes.
    pub peak_queue_bytes: f64,
    /// Link that saw it (index into `Topology::links`).
    pub peak_queue_link: usize,
    /// Deepest receive-stage (incast) queue observed, in bytes.
    pub peak_recv_queue_bytes: f64,
}

impl TailReport {
    pub fn from_stats(tail: &TailStats) -> Option<TailReport> {
        if tail.sojourn.is_empty() {
            return None;
        }
        let us = 1e6;
        let (peak_queue_link, peak_queue_bytes) = tail
            .peak_queue_bytes
            .iter()
            .enumerate()
            .fold((0, 0.0), |best, (i, &b)| if b > best.1 { (i, b) } else { best });
        Some(TailReport {
            chunks: tail.delivered_chunks,
            p50_us: tail.sojourn.quantile_s(50.0) * us,
            p95_us: tail.sojourn.quantile_s(95.0) * us,
            p99_us: tail.sojourn.quantile_s(99.0) * us,
            max_us: tail.sojourn.max_ns() as f64 * 1e-9 * us,
            transit_p99_us: tail.transit.quantile_s(99.0) * us,
            peak_queue_bytes,
            peak_queue_link,
            peak_recv_queue_bytes: tail
                .peak_recv_queue_bytes
                .iter()
                .cloned()
                .fold(0.0, f64::max),
        })
    }

    /// Nearest-rank p99 sojourn for one (src, dst) pair, when observed.
    pub fn pair_p99_us(tail: &TailStats, pair: (usize, usize)) -> Option<f64> {
        tail.per_pair_sojourn
            .get(&pair)
            .filter(|h| !h.is_empty())
            .map(|h| h.quantile_s(99.0) * 1e6)
    }
}

/// Minimal fixed-width table printer for bench/experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {:>w$} |", c, w = *w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }
}

/// Format seconds as adaptive ms/µs string.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["size", "bw (GB/s)"]);
        t.row(&["16 MB".into(), "45.1".into()]);
        t.row(&["256 MB".into(), "170.0".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
        assert!(s.contains("170.0"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.0032), "3.200 ms");
        assert_eq!(fmt_time(42e-6), "42.0 µs");
    }

    #[test]
    fn tail_report_reduces_nearest_rank() {
        use crate::util::hist::{bucket_width_ns, LatencyHist};
        let mut sojourn = LatencyHist::new();
        for i in 1..=100u64 {
            sojourn.record_ns(i * 1000); // 1..=100 µs
        }
        let mut pair = LatencyHist::new();
        for ns in [5_000u64, 9_000, 1_000] {
            pair.record_ns(ns);
        }
        let mut per_pair = std::collections::BTreeMap::new();
        per_pair.insert((0usize, 1usize), pair);
        let tail = TailStats {
            sojourn: sojourn.clone(),
            transit: sojourn,
            per_pair_sojourn: per_pair,
            peak_queue_bytes: vec![0.0, 4096.0, 512.0],
            peak_recv_queue_bytes: vec![128.0, 0.0],
            delivered_chunks: 100,
            ..TailStats::default()
        };
        let r = TailReport::from_stats(&tail).unwrap();
        // quantiles report the bucket floor: within one bucket width
        // below the exact nearest-rank sample
        let tol_us = |ns: u64| bucket_width_ns(ns) as f64 * 1e-3;
        assert!(r.p50_us <= 50.0 && 50.0 - r.p50_us <= tol_us(50_000), "p50={}", r.p50_us);
        assert!(r.p99_us <= 99.0 && 99.0 - r.p99_us <= tol_us(99_000), "p99={}", r.p99_us);
        // ... while the max stays exact
        assert!((r.max_us - 100.0).abs() < 1e-9, "max={}", r.max_us);
        assert_eq!(r.peak_queue_link, 1);
        assert_eq!(r.peak_queue_bytes, 4096.0);
        assert_eq!(r.peak_recv_queue_bytes, 128.0);
        // per-pair p99 is (the bucket floor of) the pair's worst sample
        let p = TailReport::pair_p99_us(&tail, (0, 1)).unwrap();
        assert!(p <= 9.0 && 9.0 - p <= tol_us(9_000), "pair p99={p}");
        assert!(TailReport::pair_p99_us(&tail, (3, 4)).is_none());
        // no chunks → no report
        assert!(TailReport::from_stats(&TailStats::default()).is_none());
    }
}
