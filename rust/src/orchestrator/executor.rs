//! The multi-tenant executor: every admitted job's flows fly on ONE
//! shared [`FabricBackend`], and the orchestrator plans, rebalances
//! and accounts across all of them.
//!
//! Per replan epoch (cadence from `[replan]`):
//!
//! 1. advance the shared engine to the epoch boundary;
//! 2. retire finished tenants, admit arrivals
//!    ([`super::admission::AdmissionQueue`]) — in **joint** mode the
//!    admission batch is planned by [`Planner::plan_joint`]
//!    warm-started from the exact residual routing in flight, in
//!    **independent** mode each job gets a cold per-job plan (the
//!    `--no-joint` baseline);
//! 3. sample the monitor window and rebalance: joint mode solves one
//!    joint challenger over every live tenant's residuals and accepts
//!    it **per tenant** against the other tenants' in-flight routing
//!    as exact background; independent mode runs the PR-2
//!    [`Planner::replan`] per tenant (only when `[replan]` is
//!    enabled — disabled keeps the byte-identical static path);
//! 4. accepted reroutes preempt only the changed pairs of the
//!    accepting tenant and replay through that tenant's own
//!    [`ReassemblyTable`]; the per-tenant ordering invariant is
//!    asserted on every push, exactly as in the single-job executor.
//!
//! **Weighted fairness** is enforced by channel allocation
//! ([`channel_count`]): a tenant's path parts are issued as `k`
//! parallel sub-flows (k from its weight), and on a per-flow max-min
//! fabric k parallel flows on a contended constraint draw k fair
//! shares. Sub-flows keep the PARENT transfer's saturation efficiency
//! via `rate_factor` (the channels pipeline one message, they are not
//! k small messages). The independent baseline is weight-blind: one
//! flow per part, the PR-2 layout — which is what makes a 1-job
//! `--no-joint` stream bit-identical to
//! [`crate::coordinator::ReplanExecutor`].
//!
//! **Faults** ([`FaultSchedule`], PR 7) inject at epoch boundaries,
//! exactly as in the single-job executor: each due event hits the
//! shared fabric and folds into a link-health mask handed to the joint
//! planner AND every per-tenant planner (cold admission planners
//! included), so neither admissions nor reroutes land on a link known
//! dead. Tenants stranded on a dead link bypass the z-hysteresis. An
//! empty schedule leaves every serve path bit-identical.

use super::admission::AdmissionQueue;
use super::job::{JobKind, JobSpec, TenancyCfg};
use crate::coordinator::monitor::WindowedMonitor;
use crate::coordinator::reassembly::{ChunkArrival, ReassemblyTable};
use crate::coordinator::reroute::{
    attach_reissues, pool_split_counts, preempt_and_pool, residual_routing, PartState, Reissue,
    ResidualRouting,
};
use crate::fabric::backend::{make_backend, FabricBackend, TailStats};
use crate::fabric::faults::{self, FaultSchedule};
use crate::fabric::fluid::{Flow, SimResult};
use crate::fabric::FabricParams;
use crate::planner::replan::{
    diff_pairs, drain_time_terms, drain_time_z_scaled, excess_over_plan, shape_deviation,
    top_binding, TOP_K_BINDING,
};
use crate::planner::{
    carry_plan, DrainCaps, Plan, Planner, PlannerCfg, ReplanCfg, TenantDemands,
};
use crate::telemetry::{
    emit_tail_histograms, DecisionCandidate, LinkBlame, Recorder, TraceRecord, ATTR_TOP_LINKS,
};
use crate::topology::{GpuId, Path, PathKind, Topology};
use crate::util::stats::{jain_index, percentile_nearest_rank};
use std::collections::{BTreeMap, BTreeSet};

/// Tenant weight → parallel channels (sub-flows) per path part, capped
/// at 3 (stretching light tenants further mostly extends the makespan
/// tail for no fairness gain). Weight 1.0 = one channel.
pub fn channel_count(weight: f64) -> usize {
    ((weight + 0.5).floor() as i64).clamp(1, 3) as usize
}

/// A part split into `k` channels keeps the PARENT transfer's
/// saturation efficiency: `rate_factor` restores the parent-size rate
/// ceiling on each sub-flow.
fn channel_rate_factor(
    topo: &Topology,
    params: &FabricParams,
    path: &Path,
    part_bytes: f64,
    k: usize,
) -> f64 {
    if k <= 1 {
        return 1.0;
    }
    let parent = params.flow_rate_cap_gbps(topo, path, part_bytes);
    let sub = params.flow_rate_cap_gbps(topo, path, part_bytes / k as f64);
    if sub > 0.0 {
        parent / sub
    } else {
        1.0
    }
}

struct TenantState {
    job: JobSpec,
    streams: BTreeMap<(GpuId, GpuId), Vec<PartState>>,
    chunks_per_pair: BTreeMap<(GpuId, GpuId), u64>,
    payload: f64,
    admit_s: f64,
    done: bool,
}

/// One epoch of the serve loop.
#[derive(Clone, Copy, Debug)]
pub struct ServeEpoch {
    pub t_s: f64,
    /// Peak traffic-drift indicator (joint mode: against the combined
    /// residual routing; independent mode: max over tenants).
    pub deviation: f64,
    /// Whether any tenant rerouted this epoch.
    pub replanned: bool,
    /// Flows preempted this epoch.
    pub preempted: usize,
    /// Aggregate delivered bytes over this epoch / cadence — the
    /// goodput trace `nimble faults` reads time-to-recover from.
    pub goodput_gbps: f64,
}

/// Per-tenant outcome of a serve run.
#[derive(Clone, Debug)]
pub struct TenantResult {
    pub id: usize,
    pub kind: JobKind,
    pub weight: f64,
    pub arrival_s: f64,
    /// When the job's flows were issued (epoch-quantized admission).
    pub admit_s: f64,
    pub finish_s: f64,
    pub payload_bytes: f64,
    /// payload / (finish − admit).
    pub goodput_gbps: f64,
    /// Nearest-rank p99 over the tenant's *completed* flow latencies
    /// (issue → last byte; flows aborted by preemption are excluded —
    /// their residual continues in the re-issued flows, whose
    /// latencies ARE counted). Defined on every backend.
    pub p99_lat_s: f64,
    /// Nearest-rank p99 chunk sojourn from the packet backend's
    /// per-tag streaming histogram (bucket quantile — within one
    /// bucket width, ≤3.2%, of the exact sample); `None` on the
    /// fluid backend.
    pub p99_chunk_s: Option<f64>,
    /// Peak out-of-order chunks buffered in this tenant's reassembly.
    pub peak_reassembly: usize,
}

/// Outcome of one serve run (the whole job stream).
pub struct ServeRun {
    pub tenants: Vec<TenantResult>,
    /// Virtual time when the last byte of the last tenant landed.
    pub makespan_s: f64,
    pub payload_bytes: f64,
    /// Total payload / makespan.
    pub aggregate_goodput_gbps: f64,
    /// Jain's index over per-tenant goodput normalized by weight —
    /// 1.0 when every tenant's goodput is exactly proportional to its
    /// weight (the weighted max-min fairness target).
    pub weighted_fairness: f64,
    pub replans: usize,
    pub preemptions: usize,
    pub epochs: Vec<ServeEpoch>,
    pub peak_reassembly: usize,
    pub sim: SimResult,
    pub sim_events: u64,
    /// Wall-clock time of the whole execute() loop (seconds) — with
    /// [`ServeRun::sim_events`] this yields the events/sec the serve
    /// report prints. Not deterministic; never compare it.
    pub wall_s: f64,
    /// Packet-backend tail observations (per-tag groups included).
    pub tail: Option<TailStats>,
}

impl ServeRun {
    /// Simulator throughput of this run (events per wall-clock second).
    pub fn events_per_sec(&self) -> f64 {
        self.sim_events as f64 / self.wall_s.max(1e-12)
    }
}

/// Drives a seeded job stream through admission → (joint | per-job)
/// planning → shared fabric → per-tenant reassembly. See the module
/// docs for the epoch structure and the two modes.
pub struct MultiTenantExecutor<'a> {
    pub topo: &'a Topology,
    pub params: FabricParams,
    pub planner_cfg: PlannerCfg,
    pub rcfg: ReplanCfg,
    pub tcfg: TenancyCfg,
    /// Fault events injected at epoch boundaries (empty = fault-free;
    /// the empty schedule keeps every serve path bit-identical).
    pub faults: FaultSchedule,
    /// Telemetry sink ([`Recorder::disabled`] by default — bitwise
    /// inert; see `crate::telemetry` for the observer-purity contract).
    pub rec: Recorder,
}

impl<'a> MultiTenantExecutor<'a> {
    pub fn new(
        topo: &'a Topology,
        params: FabricParams,
        planner_cfg: PlannerCfg,
        mut rcfg: ReplanCfg,
        tcfg: TenancyCfg,
    ) -> Self {
        // planner and dataplane must agree on what is endpoint-bound
        rcfg.caps = DrainCaps::from(&params);
        MultiTenantExecutor {
            topo,
            params,
            planner_cfg,
            rcfg,
            tcfg,
            faults: FaultSchedule::default(),
            rec: Recorder::disabled(),
        }
    }

    /// Attach a fault schedule; events fire at the first epoch boundary
    /// at or after their time, exactly as in the single-job executor.
    pub fn with_faults(mut self, faults: FaultSchedule) -> Self {
        self.faults = faults;
        self
    }

    /// Attach a telemetry sink (cloned recorders share one trace).
    pub fn with_recorder(mut self, rec: Recorder) -> Self {
        self.rec = rec;
        self
    }

    /// Fly the whole job stream. Deterministic: same topology, params
    /// and stream ⇒ byte-identical results at any thread count.
    pub fn execute(&mut self, jobs: Vec<JobSpec>) -> ServeRun {
        let t_exec = std::time::Instant::now();
        // wall-clock self-profiling for the `profile` trace record;
        // the disabled recorder takes no per-phase timestamps
        let mut plan_wall_s = 0.0f64;
        let mut sim_wall_s = 0.0f64;
        let topo = self.topo;
        let tcfg = self.tcfg.clone();
        let chunk = self.params.chunk_bytes.max(1.0);
        let cadence = self.rcfg.cadence_s.max(1e-6);
        let loop_on = tcfg.joint || self.rcfg.enable || !self.faults.is_empty();

        let mut shared = crate::planner::SharedConstraints::of(topo);
        let mut faults = self.faults.clone();
        faults.reset();
        let mut fault_scale = vec![1.0f64; topo.links.len()];
        let mut any_dead = false;
        let mut health_on = false;
        let mut moved_prev = 0.0f64;
        let mut stalled = 0usize;
        let mut queue = AdmissionQueue::new(jobs, tcfg.max_live);
        let mut tenants: BTreeMap<usize, TenantState> = BTreeMap::new();
        let mut planners: BTreeMap<usize, Planner<'a>> = BTreeMap::new();
        let mut joint_planner = Planner::new(topo, self.planner_cfg.clone());
        let mut engine: Option<Box<dyn FabricBackend + 'a>> = None;
        let mut n_flows = 0usize;
        let mut reass: BTreeMap<usize, ReassemblyTable> = BTreeMap::new();
        // flows aborted mid-transfer (their finish_t is the preemption
        // time, not a delivery) — excluded from the latency samples
        let mut preempted_flows: BTreeSet<usize> = BTreeSet::new();
        let mut monitor = WindowedMonitor::new(topo, cadence);
        let mut epochs: Vec<ServeEpoch> = Vec::new();
        let mut replans = 0usize;
        let mut preemptions = 0usize;

        // ---- initial admission (job 0 arrives at t = 0) ----
        self.admit(
            0.0,
            &mut queue,
            &mut tenants,
            &mut planners,
            &mut joint_planner,
            &mut engine,
            &mut n_flows,
            chunk,
            None,
        );
        assert!(engine.is_some(), "no job arrives at t = 0");

        if !loop_on {
            // no execution-time loop: hop from admission to admission,
            // then run the remainder in one shot (the byte-identical
            // static path for a 1-job stream)
            let mut t_next = cadence;
            loop {
                let eng = engine.as_mut().expect("engine exists");
                if eng.is_done() {
                    refresh_done(&mut tenants, eng.as_ref());
                }
                if queue.is_empty() {
                    let t_wall = self.rec.on().then(std::time::Instant::now);
                    eng.run_to_completion()
                        .expect("fault-free static path cannot stall");
                    if let Some(t) = t_wall {
                        sim_wall_s += t.elapsed().as_secs_f64();
                    }
                    refresh_done(&mut tenants, eng.as_ref());
                    if eng.is_done() && queue.is_empty() {
                        break;
                    }
                } else {
                    let t_wall = self.rec.on().then(std::time::Instant::now);
                    eng.advance_to(t_next)
                        .expect("bounded epoch advance cannot stall");
                    if let Some(t) = t_wall {
                        sim_wall_s += t.elapsed().as_secs_f64();
                    }
                    let t_now = t_next;
                    t_next += cadence;
                    refresh_done(&mut tenants, eng.as_ref());
                    self.admit(
                        t_now,
                        &mut queue,
                        &mut tenants,
                        &mut planners,
                        &mut joint_planner,
                        &mut engine,
                        &mut n_flows,
                        chunk,
                        None,
                    );
                }
            }
        } else {
            let mut t_next = cadence;
            let mut attr_epoch = 0u64;
            loop {
                {
                    let eng = engine.as_mut().expect("engine exists");
                    if eng.is_done() && queue.is_empty() {
                        break;
                    }
                    let t_wall = self.rec.on().then(std::time::Instant::now);
                    eng.advance_to(t_next)
                        .expect("bounded epoch advance cannot stall");
                    if let Some(t) = t_wall {
                        sim_wall_s += t.elapsed().as_secs_f64();
                    }
                }
                let t_now = t_next;
                t_next += cadence;
                // fault events take effect at the epoch boundary: hit
                // the fabric, fold into the health mask, and re-arm
                // every planner (joint + per-tenant) with it.
                let due: Vec<crate::fabric::FaultEvent> = faults.due(t_now).to_vec();
                if !due.is_empty() {
                    let eng = engine.as_mut().expect("engine exists");
                    for ev in &due {
                        eng.apply_fault(&ev.fault);
                        faults::apply_to_scale(&mut fault_scale, topo, &ev.fault);
                        self.rec.emit(|| TraceRecord::Fault {
                            t_s: t_now,
                            desc: format!("{:?}", ev.fault),
                        });
                    }
                    any_dead = fault_scale.iter().any(|&s| s <= 0.0);
                    let healthy = fault_scale.iter().all(|&s| s >= 1.0);
                    health_on = !healthy;
                    let h = if healthy { None } else { Some(fault_scale.clone()) };
                    joint_planner.set_link_health(h.clone());
                    for p in planners.values_mut() {
                        p.set_link_health(h.clone());
                    }
                    shared = if healthy {
                        crate::planner::SharedConstraints::of(topo)
                    } else {
                        crate::planner::SharedConstraints::of_scaled(topo, &fault_scale)
                    };
                }
                let goodput_gbps = {
                    let eng = engine.as_ref().expect("engine exists");
                    let moved: f64 = (0..n_flows).map(|i| eng.moved_bytes(i)).sum();
                    let g = (moved - moved_prev) / cadence / 1e9;
                    if !faults.is_empty() && moved - moved_prev <= 0.0 {
                        stalled += 1;
                        assert!(
                            stalled < 100_000,
                            "serve loop stalled: no progress for {stalled} epochs \
                             (dead link with recovery disabled?)"
                        );
                    } else {
                        stalled = 0;
                    }
                    moved_prev = moved;
                    g
                };
                refresh_done(&mut tenants, engine.as_ref().expect("engine").as_ref());
                self.admit(
                    t_now,
                    &mut queue,
                    &mut tenants,
                    &mut planners,
                    &mut joint_planner,
                    &mut engine,
                    &mut n_flows,
                    chunk,
                    if health_on { Some(&fault_scale) } else { None },
                );
                let eng = engine.as_mut().expect("engine exists");
                if eng.is_done() && queue.is_empty() {
                    if !self.faults.is_empty() {
                        epochs.push(ServeEpoch {
                            t_s: t_now,
                            deviation: 0.0,
                            replanned: false,
                            preempted: 0,
                            goodput_gbps,
                        });
                        // final partial epoch: the engine drained before
                        // the boundary, so the window was never sampled —
                        // the snapshot reports the last observed window
                        self.rec.emit(|| {
                            let snap = monitor.snapshot();
                            TraceRecord::Epoch {
                                epoch: (epochs.len() - 1) as u64,
                                t_s: t_now,
                                goodput_gbps,
                                congestion: snap.congestion,
                                deviation: 0.0,
                                replanned: false,
                                preempted: 0,
                                util: snap.util,
                            }
                        });
                    }
                    break;
                }
                // sample the engine's window; with the recorder live,
                // take the attributed form — its `totals` are produced
                // by the same canonical per-link summation, so the
                // monitor sees bit-identical bytes either way — and
                // emit the blame decomposition of the hottest links
                if self.rec.on() {
                    let attr = eng.take_window_attr();
                    let links = LinkBlame::hottest(&attr, ATTR_TOP_LINKS);
                    let epoch = attr_epoch;
                    self.rec.emit(|| TraceRecord::Attribution { t_s: t_now, epoch, links });
                    attr_epoch += 1;
                    monitor.observe(&attr.totals);
                } else {
                    monitor.observe(&eng.take_window());
                }

                // residuals per live tenant (shared extraction —
                // [`residual_routing`]; forced pairs cross a dead link)
                let live_ids: Vec<usize> = tenants
                    .iter()
                    .filter(|(_, st)| !st.done)
                    .map(|(&id, _)| id)
                    .collect();
                let mut res: BTreeMap<usize, ResidualRouting> = BTreeMap::new();
                let mut any_residual = false;
                for &tid in &live_ids {
                    let r = residual_routing(
                        &tenants[&tid].streams,
                        eng.as_ref(),
                        topo.links.len(),
                        if any_dead { Some(fault_scale.as_slice()) } else { None },
                    );
                    if !r.demands.is_empty() {
                        any_residual = true;
                    }
                    res.insert(tid, r);
                }
                if !any_residual {
                    epochs.push(ServeEpoch {
                        t_s: t_now,
                        deviation: 0.0,
                        replanned: false,
                        preempted: 0,
                        goodput_gbps,
                    });
                    self.rec.emit(|| {
                        let snap = monitor.snapshot();
                        TraceRecord::Epoch {
                            epoch: (epochs.len() - 1) as u64,
                            t_s: t_now,
                            goodput_gbps,
                            congestion: snap.congestion,
                            deviation: 0.0,
                            replanned: false,
                            preempted: 0,
                            util: snap.util,
                        }
                    });
                    continue;
                }

                let mut replanned_here = false;
                let mut preempted_here = 0usize;
                let mut epoch_batch: Vec<Flow> = Vec::new();
                let mut staged: Vec<(usize, Vec<Reissue>)> = Vec::new();
                let mut deviation = 0.0f64;

                if tcfg.joint {
                    let mut combined_ll = vec![0.0f64; topo.links.len()];
                    let mut tds: Vec<TenantDemands> = Vec::new();
                    let mut in_flight: BTreeMap<usize, Plan> = BTreeMap::new();
                    for &tid in &live_ids {
                        let r = &res[&tid];
                        if r.demands.is_empty() {
                            continue;
                        }
                        for (c, l) in combined_ll.iter_mut().zip(&r.link_load) {
                            *c += *l;
                        }
                        let mut seeds: BTreeMap<(GpuId, GpuId), PathKind> = BTreeMap::new();
                        for (k, a) in &r.assignments {
                            // first-maximal part seeds the hysteresis
                            let mut best: Option<(&Path, f64)> = None;
                            for (p, b) in &a.parts {
                                let better = match best {
                                    None => true,
                                    Some((_, bb)) => *b > bb,
                                };
                                if better {
                                    best = Some((p, *b));
                                }
                            }
                            if let Some((p, _)) = best {
                                seeds.insert(*k, p.kind);
                            }
                        }
                        let mut td =
                            TenantDemands::new(tid, tenants[&tid].job.weight, r.demands.clone());
                        td.incumbent_kinds = Some(seeds);
                        tds.push(td);
                        in_flight.insert(
                            tid,
                            Plan {
                                assignments: r.assignments.clone(),
                                link_load: r.link_load.clone(),
                                plan_time_s: 0.0,
                            },
                        );
                    }
                    let observed = monitor.load_estimates().to_vec();
                    deviation = shape_deviation(topo, &observed, &combined_ll);
                    // pressure NOT explained by the tenants' own
                    // residual routing is external background
                    let mut excess = excess_over_plan(&observed, &combined_ll);
                    let deadband = self.rcfg.margin
                        * combined_ll.iter().cloned().fold(0.0f64, f64::max);
                    for e in excess.iter_mut() {
                        *e = (*e - deadband).max(0.0);
                    }
                    let t_wall = self.rec.on().then(std::time::Instant::now);
                    let joint =
                        joint_planner.plan_joint(&tds, Some(&excess), &self.rcfg.caps, None);
                    if let Some(t) = t_wall {
                        plan_wall_s += t.elapsed().as_secs_f64();
                    }
                    // per-tenant acceptance: the challenger is evaluated
                    // against the OTHER tenants' in-flight routing as
                    // exact background (the information advantage over
                    // the independent arm's noisy monitor estimate)
                    for td in &tds {
                        let own = &in_flight[&td.tenant].link_load;
                        let bg: Vec<f64> = combined_ll
                            .iter()
                            .zip(own)
                            .zip(&excess)
                            .map(|((c, o), e)| c - o + e)
                            .collect();
                        let ch = &joint.per_tenant[&td.tenant];
                        // a tenant whose in-flight routing crosses a
                        // dead link must move: waive the hysteresis,
                        // exactly as the single-job executor does
                        let forced = !res[&td.tenant].forced.is_empty();
                        let hs = if health_on { Some(fault_scale.as_slice()) } else { None };
                        let z_carry =
                            drain_time_z_scaled(topo, &self.rcfg.caps, &shared, own, &bg, hs);
                        let z_ch = drain_time_z_scaled(
                            topo,
                            &self.rcfg.caps,
                            &shared,
                            &ch.link_load,
                            &bg,
                            hs,
                        );
                        // candidate evidence mirrors the single-job
                        // audit: built from the same drain-time terms
                        // the z figures fold, and only when recording
                        let candidates = |own: &[f64], ch_ll: &[f64]| {
                            vec![
                                DecisionCandidate {
                                    name: "carry".to_string(),
                                    z_s: z_carry,
                                    delta_s: 0.0,
                                    binding: top_binding(
                                        &drain_time_terms(
                                            topo, &self.rcfg.caps, &shared, own, &bg, hs,
                                        ),
                                        TOP_K_BINDING,
                                    ),
                                },
                                DecisionCandidate {
                                    name: "challenger".to_string(),
                                    z_s: z_ch,
                                    delta_s: z_ch - z_carry,
                                    binding: top_binding(
                                        &drain_time_terms(
                                            topo, &self.rcfg.caps, &shared, ch_ll, &bg, hs,
                                        ),
                                        TOP_K_BINDING,
                                    ),
                                },
                            ]
                        };
                        if !forced && z_ch >= z_carry * (1.0 - self.rcfg.margin) {
                            self.rec.emit(|| TraceRecord::Decision {
                                t_s: t_now,
                                tenant: td.tenant as i64,
                                accepted: false,
                                forced,
                                z_carry,
                                z_challenger: z_ch,
                                margin: self.rcfg.margin,
                                mwu_visits: joint_planner.mwu_last_visits(),
                                changed_pairs: 0,
                                candidates: candidates(own, &ch.link_load),
                            });
                            continue;
                        }
                        let changed = diff_pairs(&in_flight[&td.tenant], ch);
                        self.rec.emit(|| TraceRecord::Decision {
                            t_s: t_now,
                            tenant: td.tenant as i64,
                            accepted: !changed.is_empty(),
                            forced,
                            z_carry,
                            z_challenger: z_ch,
                            margin: self.rcfg.margin,
                            mwu_visits: joint_planner.mwu_last_visits(),
                            changed_pairs: changed.len(),
                            candidates: candidates(own, &ch.link_load),
                        });
                        if changed.is_empty() {
                            continue;
                        }
                        replanned_here = true;
                        let st = tenants.get_mut(&td.tenant).expect("live tenant");
                        let k = channel_count(st.job.weight);
                        preempted_here += reroute(
                            st,
                            eng.as_mut(),
                            reass.entry(td.tenant).or_default(),
                            ch,
                            &changed,
                            k,
                            chunk,
                            topo,
                            &self.params,
                            &mut epoch_batch,
                            &mut staged,
                            &mut preempted_flows,
                        );
                    }
                } else {
                    for &tid in &live_ids {
                        let r = &res[&tid];
                        if r.demands.is_empty() {
                            continue;
                        }
                        let in_flight = Plan {
                            assignments: r.assignments.clone(),
                            link_load: r.link_load.clone(),
                            plan_time_s: 0.0,
                        };
                        let planner = planners.get_mut(&tid).expect("tenant planner");
                        let observed = monitor.load_estimates().to_vec();
                        // pairs stranded on a dead link bypass the
                        // z-hysteresis (they would otherwise never
                        // drain — the replan IS the recovery path)
                        let t_wall = self.rec.on().then(std::time::Instant::now);
                        let out = planner.replan_forced(
                            &in_flight,
                            &observed,
                            &r.demands,
                            &self.rcfg,
                            &r.forced,
                        );
                        if let Some(t) = t_wall {
                            plan_wall_s += t.elapsed().as_secs_f64();
                        }
                        if let Some(a) = out.audit {
                            self.rec.emit(|| TraceRecord::Decision {
                                t_s: t_now,
                                tenant: tid as i64,
                                accepted: out.replanned,
                                forced: a.forced,
                                z_carry: a.z_carry,
                                z_challenger: a.z_challenger,
                                margin: a.margin,
                                mwu_visits: a.mwu_visits,
                                changed_pairs: out.changed_pairs.len(),
                                candidates: a
                                    .candidates
                                    .iter()
                                    .map(|c| DecisionCandidate {
                                        name: c.name.to_string(),
                                        z_s: c.z_s,
                                        delta_s: c.delta_s,
                                        binding: c.binding.clone(),
                                    })
                                    .collect(),
                            });
                        }
                        deviation = deviation.max(out.deviation);
                        if out.replanned {
                            replanned_here = true;
                            let st = tenants.get_mut(&tid).expect("live tenant");
                            preempted_here += reroute(
                                st,
                                eng.as_mut(),
                                reass.entry(tid).or_default(),
                                &out.plan,
                                &out.changed_pairs,
                                1,
                                chunk,
                                topo,
                                &self.params,
                                &mut epoch_batch,
                                &mut staged,
                                &mut preempted_flows,
                            );
                        }
                    }
                }
                if replanned_here {
                    replans += 1;
                    preemptions += preempted_here;
                    let first = eng.add_flows(&epoch_batch);
                    n_flows = first + epoch_batch.len();
                    for (tid, reissues) in staged {
                        let st = tenants.get_mut(&tid).expect("staged tenant");
                        attach_reissues(&mut st.streams, first, reissues);
                    }
                }
                epochs.push(ServeEpoch {
                    t_s: t_now,
                    deviation,
                    replanned: replanned_here,
                    preempted: preempted_here,
                    goodput_gbps,
                });
                self.rec.emit(|| {
                    let snap = monitor.snapshot();
                    TraceRecord::Epoch {
                        epoch: (epochs.len() - 1) as u64,
                        t_s: t_now,
                        goodput_gbps,
                        congestion: snap.congestion,
                        deviation,
                        replanned: replanned_here,
                        preempted: preempted_here,
                        util: snap.util,
                    }
                });
            }
        }
        {
            let eng = engine.as_ref().expect("engine exists");
            refresh_done(&mut tenants, eng.as_ref());
        }

        // ---- per-tenant drain through reassembly + results ----
        let eng = engine.expect("engine exists");
        let sim_events = eng.events();
        let tail = eng.tail();
        if let Some(t) = &tail {
            emit_tail_histograms(&self.rec, t);
        }
        let sim = eng.result();
        let mut results: Vec<TenantResult> = Vec::new();
        let mut peak_reass_all = 0usize;
        let mut payload_total = 0.0f64;
        for (&tid, st) in tenants.iter_mut() {
            let table = reass.entry(tid).or_default();
            for (&pair, parts) in st.streams.iter_mut() {
                let mut live = true;
                while live {
                    live = false;
                    for ps in parts.iter_mut() {
                        if ps.delivered < ps.seqs.len() {
                            table
                                .push(
                                    pair.0,
                                    pair.1,
                                    ChunkArrival {
                                        seq: ps.seqs[ps.delivered],
                                        bytes: chunk as u64,
                                    },
                                )
                                .expect("ordering invariant violated");
                            ps.delivered += 1;
                            live = true;
                        }
                    }
                }
                let q = table.stream(pair.0, pair.1).expect("stream exists");
                assert!(
                    q.is_drained(),
                    "tenant {tid} stream {pair:?} not fully reassembled"
                );
                assert_eq!(
                    q.delivered_bytes(),
                    st.chunks_per_pair[&pair] * chunk as u64,
                    "tenant {tid} stream {pair:?} lost chunks across reroutes"
                );
            }
            let mut finish = 0.0f64;
            let mut lat: Vec<f64> = Vec::new();
            for parts in st.streams.values() {
                for ps in parts {
                    let f = &sim.flows[ps.flow];
                    if f.finish_t.is_nan() {
                        continue;
                    }
                    finish = finish.max(f.finish_t);
                    if !preempted_flows.contains(&ps.flow) {
                        lat.push(f.finish_t - eng.flow(ps.flow).issue_t);
                    }
                }
            }
            let peak = st
                .streams
                .keys()
                .filter_map(|&(s, d)| table.stream(s, d).map(|q| q.peak_pending))
                .max()
                .unwrap_or(0);
            peak_reass_all = peak_reass_all.max(peak);
            payload_total += st.payload;
            results.push(TenantResult {
                id: tid,
                kind: st.job.kind,
                weight: st.job.weight,
                arrival_s: st.job.arrival_s,
                admit_s: st.admit_s,
                finish_s: finish,
                payload_bytes: st.payload,
                goodput_gbps: st.payload / (finish - st.admit_s).max(1e-12) / 1e9,
                p99_lat_s: if lat.is_empty() {
                    0.0
                } else {
                    percentile_nearest_rank(&lat, 99.0)
                },
                p99_chunk_s: tail.as_ref().and_then(|t| {
                    t.per_tag_sojourn
                        .get(&(tid as u64))
                        .filter(|h| !h.is_empty())
                        .map(|h| h.quantile_s(99.0))
                }),
                peak_reassembly: peak,
            });
        }
        let g_over_w: Vec<f64> = results.iter().map(|t| t.goodput_gbps / t.weight).collect();
        let makespan = sim.makespan;
        let aggregate_goodput_gbps = payload_total / makespan.max(1e-12) / 1e9;
        for t in &results {
            self.rec.emit(|| TraceRecord::Tenant {
                tenant: t.id as u64,
                tenant_kind: format!("{:?}", t.kind),
                weight: t.weight,
                admit_s: t.admit_s,
                finish_s: t.finish_s,
                payload_bytes: t.payload_bytes,
                goodput_gbps: t.goodput_gbps,
                p99_lat_s: t.p99_lat_s,
                p99_chunk_s: t.p99_chunk_s.unwrap_or(-1.0),
            });
        }
        self.rec.emit(|| TraceRecord::Summary {
            makespan_s: makespan,
            payload_bytes: payload_total,
            goodput_gbps: aggregate_goodput_gbps,
            replans: replans as u64,
            preemptions: preemptions as u64,
            sim_events,
        });
        self.rec.emit(|| TraceRecord::Profile {
            engine: eng.profile(),
            mwu_plans: joint_planner.mwu_plans()
                + planners.values().map(|p| p.mwu_plans()).sum::<u64>(),
            mwu_visits: joint_planner.mwu_total_visits()
                + planners.values().map(|p| p.mwu_total_visits()).sum::<u64>(),
            plan_wall_s,
            sim_wall_s,
        });
        ServeRun {
            weighted_fairness: if g_over_w.is_empty() {
                1.0
            } else {
                jain_index(&g_over_w)
            },
            tenants: results,
            makespan_s: makespan,
            payload_bytes: payload_total,
            aggregate_goodput_gbps,
            replans,
            preemptions,
            epochs,
            peak_reassembly: peak_reass_all,
            sim,
            sim_events,
            wall_s: t_exec.elapsed().as_secs_f64(),
            tail,
        }
    }

    /// Admit every job arriving by `t_now` that fits under the
    /// concurrency cap, plan the batch (jointly or per job) and issue
    /// its flows at the epoch boundary. `health` is the current fault
    /// mask: per-job cold planners must see it so admissions never
    /// route onto a link already known dead (the joint planner carries
    /// it persistently).
    #[allow(clippy::too_many_arguments)]
    fn admit(
        &self,
        t_now: f64,
        queue: &mut AdmissionQueue,
        tenants: &mut BTreeMap<usize, TenantState>,
        planners: &mut BTreeMap<usize, Planner<'a>>,
        joint_planner: &mut Planner<'a>,
        engine: &mut Option<Box<dyn FabricBackend + 'a>>,
        n_flows: &mut usize,
        chunk: f64,
        health: Option<&Vec<f64>>,
    ) {
        let topo = self.topo;
        let live = tenants.values().filter(|st| !st.done).count();
        let batch = queue.pop_admissible(t_now, live);
        if batch.is_empty() {
            return;
        }
        let start = t_now;
        let mut plans: BTreeMap<usize, Plan> = BTreeMap::new();
        if self.tcfg.joint {
            // plan the admission batch jointly, warm-started from the
            // exact residual routing already in flight (the monitor
            // would only re-measure the same flows, noisily)
            let (init, ep_init) = match engine {
                Some(eng) => residual_link_load(topo, tenants, eng.as_ref()),
                None => (
                    vec![0.0; topo.links.len()],
                    vec![0.0; crate::planner::joint::joint_endpoint_slots(topo)],
                ),
            };
            let tds: Vec<TenantDemands> = batch
                .iter()
                .map(|j| TenantDemands::new(j.id, j.weight, j.demands(topo)))
                .collect();
            let joint =
                joint_planner.plan_joint(&tds, Some(&init), &self.rcfg.caps, Some(&ep_init));
            plans = joint.per_tenant;
        } else {
            for j in &batch {
                let mut planner = Planner::new(topo, self.planner_cfg.clone());
                if let Some(h) = health {
                    planner.set_link_health(Some(h.clone()));
                }
                let d = j.demands(topo);
                let plan = carry_plan(topo, &planner.plan(&d), &d);
                planners.insert(j.id, planner);
                plans.insert(j.id, plan);
            }
        }
        let mut batch_flows: Vec<Flow> = Vec::new();
        for j in &batch {
            let d = j.demands(topo);
            let payload: f64 = d.iter().map(|x| x.bytes).sum();
            let mut st = TenantState {
                job: j.clone(),
                streams: BTreeMap::new(),
                chunks_per_pair: BTreeMap::new(),
                payload,
                admit_s: start,
                done: false,
            };
            let k = if self.tcfg.joint { channel_count(j.weight) } else { 1 };
            self.rec.emit(|| TraceRecord::Admit {
                t_s: start,
                tenant: j.id as u64,
                tenant_kind: format!("{:?}", j.kind),
                weight: j.weight,
                payload_bytes: payload,
                channels: k,
            });
            let mut idx = *n_flows + batch_flows.len();
            let plan = &plans[&j.id];
            for (&pair, a) in &plan.assignments {
                let mut base = *st.chunks_per_pair.get(&pair).unwrap_or(&0);
                let parts = st.streams.entry(pair).or_default();
                for (path, bytes) in &a.parts {
                    let rf = channel_rate_factor(topo, &self.params, path, *bytes, k);
                    for _ in 0..k {
                        let sub = bytes / k as f64;
                        let n = (sub / chunk).ceil().max(1.0) as u64;
                        parts.push(PartState {
                            flow: idx,
                            seqs: (base..base + n).collect(),
                            delivered: 0,
                        });
                        batch_flows.push(
                            Flow::new(path.clone(), sub)
                                .at(start)
                                .with_rate_factor(rf)
                                .tagged(j.id as u64),
                        );
                        idx += 1;
                        base += n;
                    }
                }
                st.chunks_per_pair.insert(pair, base);
            }
            tenants.insert(j.id, st);
        }
        match engine {
            Some(eng) => {
                let first = eng.add_flows(&batch_flows);
                *n_flows = first + batch_flows.len();
            }
            None => {
                *n_flows = batch_flows.len();
                *engine = Some(make_backend(topo, self.params.clone(), &batch_flows));
            }
        }
    }
}

/// Mark tenants whose every flow has left the fabric as done (their
/// admission slot frees).
fn refresh_done(tenants: &mut BTreeMap<usize, TenantState>, engine: &dyn FabricBackend) {
    for st in tenants.values_mut() {
        if st.done {
            continue;
        }
        let alive = st
            .streams
            .values()
            .any(|parts| parts.iter().any(|ps| engine.is_live(ps.flow)));
        if !alive {
            st.done = true;
        }
    }
}

/// Exact residual routing of every live tenant, as link loads plus the
/// joint planner's virtual endpoint loads (the admission warm start).
fn residual_link_load(
    topo: &Topology,
    tenants: &BTreeMap<usize, TenantState>,
    engine: &dyn FabricBackend,
) -> (Vec<f64>, Vec<f64>) {
    let mut ll = vec![0.0f64; topo.links.len()];
    let mut ep = vec![0.0f64; crate::planner::joint::joint_endpoint_slots(topo)];
    for st in tenants.values() {
        if st.done {
            continue;
        }
        for parts in st.streams.values() {
            for ps in parts {
                let r = engine.residual_bytes(ps.flow);
                if r > 1.0 {
                    let path = &engine.flow(ps.flow).path;
                    for &h in &path.hops {
                        ll[h] += r;
                    }
                    for e in crate::planner::joint::path_relay_endpoints(topo, path) {
                        ep[e] += r;
                    }
                }
            }
        }
    }
    (ll, ep)
}

/// Preempt the changed pairs of one tenant and stage their residuals
/// on the new plan's paths (k channels per part); returns the number
/// of flows preempted. The re-issued flows are appended to the shared
/// epoch batch; `staged` records how the pooled chunk sequences map
/// onto them once the batch registers.
#[allow(clippy::too_many_arguments)]
fn reroute(
    st: &mut TenantState,
    engine: &mut dyn FabricBackend,
    reass: &mut ReassemblyTable,
    newplan: &Plan,
    changed: &[(GpuId, GpuId)],
    k: usize,
    chunk: f64,
    topo: &Topology,
    params: &FabricParams,
    epoch_batch: &mut Vec<Flow>,
    staged: &mut Vec<(usize, Vec<Reissue>)>,
    preempted_flows: &mut BTreeSet<usize>,
) -> usize {
    let mut preempted_here = 0usize;
    let mut reissues: Vec<Reissue> = Vec::new();
    let now = engine.now();
    let tag = st.job.id as u64;
    for &pair in changed {
        let Some(newa) = newplan.assignments.get(&pair) else { continue };
        let Some(parts) = st.streams.get_mut(&pair) else { continue };
        // preempt live parts; release their completed chunk prefixes;
        // pool the undelivered seqs
        let (pool, n_pre) = preempt_and_pool(&mut *engine, reass, pair, parts, chunk, &mut |f| {
            preempted_flows.insert(f);
        });
        preempted_here += n_pre;
        // stage the residual on the new paths (k channels per part);
        // the pooled seqs split across the sub-flows by byte share
        let mut subparts: Vec<(Path, f64, f64)> = Vec::new();
        for (path, bytes) in &newa.parts {
            let rf = channel_rate_factor(topo, params, path, *bytes, k);
            for _ in 0..k {
                subparts.push((path.clone(), *bytes / k as f64, rf));
            }
        }
        let total_new: f64 = {
            let mut t = 0.0;
            for (_, b, _) in &subparts {
                t += *b;
            }
            t.max(1.0)
        };
        let batch_off = epoch_batch.len();
        let mut shares: Vec<f64> = Vec::with_capacity(subparts.len());
        for (path, bytes, rf) in &subparts {
            epoch_batch.push(
                Flow::new(path.clone(), *bytes)
                    .at(now)
                    .with_rate_factor(*rf)
                    .tagged(tag),
            );
            shares.push(*bytes);
        }
        let counts = pool_split_counts(&shares, total_new, pool.len());
        reissues.push(Reissue { pair, batch_off, counts, pool });
    }
    staged.push((st.job.id, reissues));
    preempted_here
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::job::job_stream;

    fn exec<'a>(
        topo: &'a Topology,
        joint: bool,
        enable: bool,
    ) -> MultiTenantExecutor<'a> {
        let tcfg = TenancyCfg { joint, ..TenancyCfg::default() };
        let rcfg = ReplanCfg { enable, ..ReplanCfg::default() };
        MultiTenantExecutor::new(topo, FabricParams::default(), PlannerCfg::default(), rcfg, tcfg)
    }

    #[test]
    fn channel_count_maps_weights() {
        assert_eq!(channel_count(1.0), 1);
        assert_eq!(channel_count(2.0), 2);
        assert_eq!(channel_count(4.0), 3, "capped at 3");
        assert_eq!(channel_count(0.4), 1, "floor clamps up to 1");
    }

    /// The default 8-job stream completes on the shared fabric with
    /// every tenant's payload conserved through its own reassembly
    /// (asserted inside execute) and sane per-tenant accounting.
    #[test]
    fn serve_stream_completes_and_accounts() {
        let topo = Topology::paper();
        let tcfg = TenancyCfg::default();
        let jobs = job_stream(&topo, &tcfg);
        let run = exec(&topo, true, false).execute(jobs.clone());
        assert_eq!(run.tenants.len(), jobs.len());
        for (t, j) in run.tenants.iter().zip(&jobs) {
            assert_eq!(t.id, j.id);
            assert!(t.goodput_gbps > 0.0, "tenant {} starved", t.id);
            assert!(t.finish_s > t.admit_s);
            assert!(t.admit_s >= j.arrival_s - 1e-15, "admitted before arrival");
        }
        assert!(run.makespan_s > 0.0);
        assert!(run.weighted_fairness > 0.0 && run.weighted_fairness <= 1.0);
        // the stream overlaps: rebalancing fired at least once
        assert!(run.replans >= 1, "no joint rebalance on the default stream");
        assert!(run.preemptions >= 1);
        assert!(run.peak_reassembly >= 1, "no out-of-order buffering");
    }

    /// Same seed ⇒ byte-identical serve outcome, run to run.
    #[test]
    fn serve_is_deterministic() {
        let topo = Topology::paper();
        let tcfg = TenancyCfg { jobs: 4, ..TenancyCfg::default() };
        let jobs = job_stream(&topo, &tcfg);
        let run = |jobs: Vec<JobSpec>| {
            let mut ex = exec(&topo, true, false);
            ex.tcfg = tcfg.clone();
            ex.execute(jobs)
        };
        let a = run(jobs.clone());
        let b = run(jobs);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.replans, b.replans);
        assert_eq!(a.preemptions, b.preemptions);
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(x.goodput_gbps.to_bits(), y.goodput_gbps.to_bits());
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
            assert_eq!(x.p99_lat_s.to_bits(), y.p99_lat_s.to_bits());
        }
        for (x, y) in a.sim.link_bytes.iter().zip(&b.sim.link_bytes) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// A mid-stream link flap on the shared fabric: every tenant still
    /// completes with its payload conserved through reassembly
    /// (asserted inside execute), the goodput trace records the epoch
    /// series, and the faulted serve run is deterministic.
    #[test]
    fn serve_survives_link_flap_and_stays_deterministic() {
        use crate::fabric::{Fault, FaultEvent, FaultSchedule};
        let topo = Topology::paper();
        let tcfg = TenancyCfg { jobs: 3, ..TenancyCfg::default() };
        let jobs = job_stream(&topo, &tcfg);
        let link = topo.rail(0, 1, 0).expect("rail link");
        let sched = FaultSchedule::new(vec![
            FaultEvent { t_s: 1.0e-3, fault: Fault::LinkDown { link } },
            FaultEvent { t_s: 3.0e-3, fault: Fault::LinkUp { link } },
        ]);
        let rcfg =
            ReplanCfg { enable: true, cadence_s: 2.0e-4, margin: 0.1, ..ReplanCfg::default() };
        let run_once = || {
            let mut ex = MultiTenantExecutor::new(
                &topo,
                FabricParams::default(),
                PlannerCfg::default(),
                rcfg.clone(),
                tcfg.clone(),
            )
            .with_faults(sched.clone());
            ex.execute(jobs.clone())
        };
        let a = run_once();
        assert_eq!(a.tenants.len(), jobs.len());
        for t in &a.tenants {
            assert!(t.goodput_gbps > 0.0, "tenant {} starved", t.id);
        }
        assert!(!a.epochs.is_empty(), "faulted serve loop never sampled");
        assert!(
            a.epochs.iter().any(|e| e.goodput_gbps > 0.0),
            "goodput trace empty under faults"
        );
        let b = run_once();
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.replans, b.replans);
        assert_eq!(a.preemptions, b.preemptions);
    }

    /// The packet backend serves the stream too (backend-agnostic loop)
    /// and groups tail observations by tenant tag.
    #[test]
    fn serve_runs_on_packet_backend_with_per_tenant_tails() {
        let topo = Topology::paper();
        let params = FabricParams {
            backend: crate::fabric::BackendKind::Packet,
            ..FabricParams::default()
        };
        let tcfg = TenancyCfg { jobs: 3, ..TenancyCfg::default() };
        let jobs = job_stream(&topo, &tcfg);
        let mut ex = MultiTenantExecutor::new(
            &topo,
            params,
            PlannerCfg::default(),
            ReplanCfg::default(),
            tcfg,
        );
        let run = ex.execute(jobs);
        let tail = run.tail.expect("packet backend records tails");
        assert!(tail.delivered_chunks > 0);
        assert_eq!(tail.sojourn.total(), tail.delivered_chunks);
        let per_tag_total: u64 = tail.per_tag_sojourn.values().map(|h| h.total()).sum();
        assert_eq!(per_tag_total, tail.delivered_chunks, "tag groups partition deliveries");
        for t in &run.tenants {
            assert!(t.goodput_gbps > 0.0);
            let p99 = t.p99_chunk_s.expect("per-tenant chunk tail");
            assert!(p99 > 0.0);
        }
    }
}
