//! Multi-tenant orchestrator: concurrent collective jobs sharing one
//! fabric, with joint planning, weighted fairness and per-tenant
//! execution-time rebalancing.
//!
//! The §V-E interference experiment re-slices *one* job around opaque
//! background load; this subsystem is the cross-job scheduler it
//! explicitly was not. Data flow:
//!
//! ```text
//!   job stream ──► admission ──► joint plan ──► shared fabric backend
//!  ([job_stream])  ([admission])  ([`Planner::plan_joint`])   (fluid | packet)
//!        ▲              │                ▲                        │
//!        seed           └─ slot frees ◄──┼── monitor window ◄─────┤
//!                                        │                        ▼
//!                            per-tenant accept + preempt   per-tenant
//!                            ([executor] epoch loop)       reassembly
//! ```
//!
//! * [`job::job_stream`] derives a whole job stream (arrivals, weights,
//!   workload kinds and parameters) from one seed — the determinism
//!   contract extends to multi-tenancy: same seed + config ⇒
//!   byte-identical schedule and results at any thread count.
//! * [`admission::AdmissionQueue`] holds jobs between arrival and
//!   admission (FIFO, concurrency-capped, epoch-quantized).
//! * [`executor::MultiTenantExecutor`] flies every admitted tenant on
//!   one shared [`crate::fabric::FabricBackend`] — fluid or packet, the
//!   loop is backend-agnostic — planning admissions and epoch
//!   challengers jointly ([`crate::planner::Planner::plan_joint`]),
//!   enforcing tenant weights via channel allocation, and replaying
//!   every reroute through the owning tenant's own
//!   [`crate::coordinator::reassembly::ReassemblyTable`].
//!
//! `--no-joint` (or `[tenancy] joint = false`) degrades to independent
//! per-job plans with the PR-2 semantics: a 1-job stream is then
//! bit-identical to [`crate::coordinator::ReplanExecutor`] — enabled
//! or disabled `[replan]`, both anchors hold (see
//! `tests/integration.rs`). `nimble serve` drives the whole thing;
//! DESIGN.md §11 states what joint planning does and does not
//! guarantee.
//!
//! [`Planner::plan_joint`]: crate::planner::Planner::plan_joint
//! [`job_stream`]: job::job_stream
//! [admission]: admission::AdmissionQueue
//! [executor]: executor::MultiTenantExecutor

pub mod admission;
pub mod executor;
pub mod job;

pub use admission::AdmissionQueue;
pub use executor::{channel_count, MultiTenantExecutor, ServeEpoch, ServeRun, TenantResult};
pub use job::{job_stream, JobKind, JobSpec, TenancyCfg};
