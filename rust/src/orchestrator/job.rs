//! Seeded multi-tenant job stream: who arrives when, with what weight,
//! flying which collective.
//!
//! A [`JobSpec`] is one collective job — arrival time, fairness weight
//! and a workload drawn from the existing generators (skewed
//! All-to-Allv, phased hot rows, MoE drift, boundary-hotspot stencil,
//! imbalanced Send/Recv). [`job_stream`] derives the whole stream from
//! one [`TenancyCfg`] seed, so a serve run is a pure function of its
//! config: same seed ⇒ byte-identical jobs ⇒ byte-identical schedule
//! and results (the determinism contract of DESIGN.md §9 extended to
//! multi-tenancy).

use crate::collectives::sendrecv::imbalanced_batch;
use crate::planner::Demand;
use crate::topology::Topology;
use crate::util::rng::Rng;
use crate::workloads::skew::hotspot_alltoallv;
use crate::workloads::stencil::stencil_1d_hotspot;
use crate::workloads::{MoeDrift, PhasedHotRows};

const MB: f64 = 1024.0 * 1024.0;

/// Workload family of one job. The per-kind parameters live in
/// [`JobSpec::a`]/[`JobSpec::b`]/[`JobSpec::c`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// §V-C skewed All-to-Allv: a = payload/rank, b = hotspot ratio,
    /// c = hot destination.
    SkewedAlltoall,
    /// Phase-shifting hot rows: a = row bytes, c = phase round.
    HotRows,
    /// MoE expert-popularity drift: a = global tokens, c = drift round.
    MoeDrift,
    /// Boundary-hotspot stencil: a = halo bytes, b = hot factor.
    Stencil,
    /// Imbalanced async Send/Recv batch: a = base bytes, b = imbalance.
    SendRecv,
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::SkewedAlltoall => "skewed-a2a",
            JobKind::HotRows => "hot-rows",
            JobKind::MoeDrift => "moe-drift",
            JobKind::Stencil => "stencil",
            JobKind::SendRecv => "sendrecv",
        }
    }
}

/// One tenant job of the serve stream.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Stable id (stream position); doubles as the flow tag and the
    /// joint-planner tenant id.
    pub id: usize,
    /// Virtual arrival time in seconds (job 0 arrives at 0).
    pub arrival_s: f64,
    /// Fairness weight (drives the MWU λ scaling and the channel
    /// allocation; the weighted fairness index normalizes by it).
    pub weight: f64,
    pub kind: JobKind,
    /// First per-kind parameter (bytes or tokens; see [`JobKind`]).
    pub a: f64,
    /// Second per-kind parameter (ratio / factor; see [`JobKind`]).
    pub b: f64,
    /// Third per-kind parameter (hot destination / round).
    pub c: f64,
}

impl JobSpec {
    /// Materialize the job's demand set on `topo`. Pure: every call
    /// returns byte-identical demands.
    pub fn demands(&self, topo: &Topology) -> Vec<Demand> {
        match self.kind {
            JobKind::SkewedAlltoall => {
                hotspot_alltoallv(topo, self.a, self.b, self.c as usize)
            }
            JobKind::HotRows => {
                PhasedHotRows::paper_default(topo, self.a).demands_at(topo, self.c as usize)
            }
            JobKind::MoeDrift => MoeDrift::paper_default(topo, self.a as usize)
                .demands_at(topo, self.c as usize),
            JobKind::Stencil => stencil_1d_hotspot(topo, self.a, self.b),
            JobKind::SendRecv => imbalanced_batch(topo, self.a, self.b),
        }
    }

    /// Total payload bytes of the job.
    pub fn payload(&self, topo: &Topology) -> f64 {
        self.demands(topo).iter().map(|d| d.bytes).sum()
    }
}

/// `[tenancy]` configuration (see `configs/paper.toml`). Only consumed
/// by `nimble serve` / the orchestrator, so the section is inert for
/// every other experiment.
#[derive(Clone, Debug)]
pub struct TenancyCfg {
    /// Jobs in the stream (≥ 1).
    pub jobs: usize,
    /// Arrival/workload seed; the whole stream derives from it.
    pub seed: u64,
    /// Fairness weights, cycled over the stream (finite, positive).
    pub weights: Vec<f64>,
    /// Admission cap: jobs concurrently in flight (FIFO beyond it).
    pub max_live: usize,
    /// Mean inter-arrival gap in milliseconds (jittered ±75%).
    pub mean_gap_ms: f64,
    /// Joint planning + weighted channels + cross-tenant rebalancing;
    /// `false` = independent per-job plans (the `--no-joint` baseline,
    /// bit-identical to [`crate::coordinator::ReplanExecutor`] on a
    /// 1-job stream).
    pub joint: bool,
}

impl Default for TenancyCfg {
    fn default() -> Self {
        TenancyCfg {
            jobs: 8,
            seed: 3,
            weights: vec![1.0, 2.0, 1.0, 4.0],
            max_live: 6,
            mean_gap_ms: 0.5,
            joint: true,
        }
    }
}

impl TenancyCfg {
    /// Validate the knobs (the config loader and CLI both call this).
    pub fn validate(&self) -> Result<(), String> {
        if self.jobs == 0 {
            return Err("tenancy.jobs must be >= 1".into());
        }
        if self.jobs > 4096 {
            return Err(format!("tenancy.jobs out of [1, 4096]: {}", self.jobs));
        }
        if self.weights.is_empty() {
            return Err("tenancy.weights must not be empty".into());
        }
        for w in &self.weights {
            if !w.is_finite() || *w <= 0.0 {
                return Err(format!(
                    "tenancy.weights must be finite and positive, got {w}"
                ));
            }
        }
        if self.max_live == 0 {
            return Err("tenancy.max_live must be >= 1".into());
        }
        if !self.mean_gap_ms.is_finite() || self.mean_gap_ms <= 0.0 {
            return Err(format!(
                "tenancy.mean_gap_ms must be positive: {}",
                self.mean_gap_ms
            ));
        }
        Ok(())
    }
}

/// Generate the seeded job stream: job 0 arrives at t = 0, later
/// arrivals are jittered around the mean gap; kinds and per-kind
/// parameters all come from one [`Rng`]. The kind mix skews toward
/// link-bound patterns (p2p, stencil, skewed A2A) where routing choice
/// matters; the endpoint-bound full-coverage patterns (hot rows, MoE)
/// appear but do not dominate.
pub fn job_stream(topo: &Topology, tcfg: &TenancyCfg) -> Vec<JobSpec> {
    let mut rng = Rng::new(tcfg.seed);
    let n = topo.num_gpus();
    let mut jobs = Vec::with_capacity(tcfg.jobs);
    let mut t = 0.0f64;
    for i in 0..tcfg.jobs {
        if i > 0 {
            t += tcfg.mean_gap_ms * 1e-3 * rng.range_f64(0.25, 1.75);
        }
        let weight = tcfg.weights[i % tcfg.weights.len()];
        let draw = rng.below(8) as usize;
        let kind = [
            JobKind::SkewedAlltoall,
            JobKind::SkewedAlltoall,
            JobKind::HotRows,
            JobKind::MoeDrift,
            JobKind::Stencil,
            JobKind::Stencil,
            JobKind::SendRecv,
            JobKind::SendRecv,
        ][draw];
        let (a, b, c) = match kind {
            JobKind::SkewedAlltoall => (
                rng.range_f64(24.0, 56.0) * MB,
                rng.range_f64(0.6, 0.9),
                rng.below(n as u64) as f64,
            ),
            JobKind::HotRows => {
                (rng.range_f64(24.0, 56.0) * MB, 0.0, rng.below(4) as f64)
            }
            JobKind::MoeDrift => {
                ((16384u64 << rng.below(2)) as f64, 0.0, rng.below(8) as f64)
            }
            JobKind::Stencil => (rng.range_f64(32.0, 96.0) * MB, rng.range_f64(2.0, 4.0), 0.0),
            JobKind::SendRecv => (rng.range_f64(16.0, 48.0) * MB, rng.range_f64(2.0, 8.0), 0.0),
        };
        jobs.push(JobSpec { id: i, arrival_s: t, weight, kind, a, b, c });
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_seeded_and_ordered() {
        let t = Topology::paper();
        let cfg = TenancyCfg::default();
        let a = job_stream(&t, &cfg);
        let b = job_stream(&t, &cfg);
        assert_eq!(a.len(), cfg.jobs);
        assert_eq!(a[0].arrival_s, 0.0, "job 0 must arrive at t = 0");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.a.to_bits(), y.a.to_bits());
        }
        // strictly increasing arrivals
        for w in a.windows(2) {
            assert!(w[0].arrival_s < w[1].arrival_s);
        }
        // a different seed changes the stream
        let cfg2 = TenancyCfg { seed: 1234, ..TenancyCfg::default() };
        let c = job_stream(&t, &cfg2);
        assert!(a
            .iter()
            .zip(&c)
            .any(|(x, y)| x.kind != y.kind || x.a.to_bits() != y.a.to_bits()));
    }

    #[test]
    fn demands_are_pure_and_nonempty() {
        let t = Topology::paper();
        let jobs = job_stream(&t, &TenancyCfg::default());
        for j in &jobs {
            let d1 = j.demands(&t);
            let d2 = j.demands(&t);
            assert!(!d1.is_empty(), "job {} ({}) empty", j.id, j.kind.name());
            assert_eq!(d1.len(), d2.len());
            for (x, y) in d1.iter().zip(&d2) {
                assert_eq!(x.bytes.to_bits(), y.bytes.to_bits());
            }
            assert!(j.payload(&t) > 0.0);
        }
    }

    #[test]
    fn weights_cycle_and_validation_rejects_bad_knobs() {
        let t = Topology::paper();
        let cfg = TenancyCfg::default();
        let jobs = job_stream(&t, &cfg);
        for j in &jobs {
            assert_eq!(j.weight, cfg.weights[j.id % cfg.weights.len()]);
        }
        assert!(cfg.validate().is_ok());
        let bad = |f: &dyn Fn(&mut TenancyCfg)| {
            let mut c = TenancyCfg::default();
            f(&mut c);
            c.validate().is_err()
        };
        assert!(bad(&|c| c.jobs = 0));
        assert!(bad(&|c| c.weights = vec![]));
        assert!(bad(&|c| c.weights = vec![1.0, -2.0]));
        assert!(bad(&|c| c.weights = vec![f64::NAN]));
        assert!(bad(&|c| c.max_live = 0));
        assert!(bad(&|c| c.mean_gap_ms = 0.0));
    }
}
