//! Admission / queueing layer of the multi-tenant orchestrator.
//!
//! Jobs wait here between their arrival time and their admission onto
//! the shared fabric. Policy (deterministic by construction):
//!
//! * **FIFO in arrival order** — [`crate::orchestrator::job_stream`]
//!   generates strictly increasing arrivals, so stream order IS
//!   arrival order. Head-of-line blocking under a full fabric is
//!   accepted and documented (DESIGN.md §11).
//! * **Concurrency cap** — at most [`TenancyCfg::max_live`] tenants in
//!   flight; a slot frees when every flow of a tenant has delivered.
//! * **Epoch-quantized** — admissions happen at the executor's replan
//!   epoch boundaries, so the whole schedule stays a pure function of
//!   (config, seed).
//!
//! [`TenancyCfg::max_live`]: crate::orchestrator::TenancyCfg::max_live

use super::job::JobSpec;
use std::collections::VecDeque;

/// FIFO admission queue with a live-tenant concurrency cap.
#[derive(Debug)]
pub struct AdmissionQueue {
    queue: VecDeque<JobSpec>,
    /// Concurrency cap (jobs in flight).
    pub max_live: usize,
}

impl AdmissionQueue {
    /// Build from a job stream (must be sorted by arrival; the seeded
    /// generator guarantees it — debug-asserted here).
    pub fn new(jobs: Vec<JobSpec>, max_live: usize) -> Self {
        debug_assert!(
            jobs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s),
            "job stream must be sorted by arrival"
        );
        AdmissionQueue { queue: jobs.into(), max_live }
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Pop every job admissible at `t_now` given `live` tenants already
    /// in flight: arrived (`arrival_s <= t_now`, with the same 1e-15
    /// slack the fabric uses for start times) and fitting under the
    /// concurrency cap. FIFO: a blocked head blocks everything behind
    /// it.
    pub fn pop_admissible(&mut self, t_now: f64, live: usize) -> Vec<JobSpec> {
        let mut batch = Vec::new();
        while let Some(head) = self.queue.front() {
            if head.arrival_s <= t_now + 1e-15 && live + batch.len() < self.max_live {
                batch.push(self.queue.pop_front().expect("head exists"));
            } else {
                break;
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::job::JobKind;

    fn job(id: usize, arrival_s: f64) -> JobSpec {
        JobSpec {
            id,
            arrival_s,
            weight: 1.0,
            kind: JobKind::SendRecv,
            a: 1e6,
            b: 2.0,
            c: 0.0,
        }
    }

    #[test]
    fn admits_in_fifo_order_up_to_cap() {
        let jobs = vec![job(0, 0.0), job(1, 0.0), job(2, 0.0), job(3, 1.0)];
        let mut q = AdmissionQueue::new(jobs, 2);
        let b = q.pop_admissible(0.0, 0);
        assert_eq!(b.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1]);
        // cap full: nothing admitted even though job 2 has arrived
        assert!(q.pop_admissible(0.0, 2).is_empty());
        // one slot frees: job 2 goes, job 3 has not arrived yet
        let b = q.pop_admissible(0.5, 1);
        assert_eq!(b.iter().map(|j| j.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(q.len(), 1);
        let b = q.pop_admissible(1.0, 0);
        assert_eq!(b.iter().map(|j| j.id).collect::<Vec<_>>(), vec![3]);
        assert!(q.is_empty());
    }

    #[test]
    fn head_of_line_blocks_later_arrivals() {
        // head arrives later than the job behind it would be ready —
        // FIFO still waits for the head (arrival order is queue order)
        let jobs = vec![job(0, 0.0), job(1, 2.0), job(2, 2.0)];
        let mut q = AdmissionQueue::new(jobs, 8);
        assert_eq!(q.pop_admissible(0.0, 0).len(), 1);
        assert!(q.pop_admissible(1.0, 1).is_empty(), "head not yet arrived");
        assert_eq!(q.pop_admissible(2.0, 1).len(), 2);
    }
}
