//! Asynchronous point-to-point Send/Recv batches (paper §I evaluation
//! highlight: "1.15–2.3× speedup at 8 MB and up to 3.4× at 256 MB over
//! the baseline as imbalance grows").
//!
//! A batch is a set of concurrent p2p transfers with per-stream sizes;
//! imbalance concentrates bytes on a few streams so their default
//! links saturate while others idle — exactly where multi-path
//! splitting pays.

use crate::baselines::{run_round, Router};
use crate::fabric::FabricParams;
use crate::metrics::CommReport;
use crate::planner::Demand;
use crate::topology::Topology;

/// Run a batch of concurrent sends.
pub fn sendrecv_batch(
    topo: &Topology,
    params: &FabricParams,
    router: &mut dyn Router,
    sends: &[Demand],
) -> CommReport {
    run_round(topo, params, router, sends)
}

/// Build an imbalanced concurrent batch: `streams` p2p transfers
/// between distinct intra-node pairs; stream 0 carries
/// `imbalance ×` the bytes of the others so its direct link becomes
/// the bottleneck. Total volume is `streams × base_bytes` regardless
/// of imbalance (skew moves bytes, not adds them).
pub fn imbalanced_batch(topo: &Topology, base_bytes: f64, imbalance: f64) -> Vec<Demand> {
    assert!(imbalance >= 1.0);
    // 2 streams on node 0: (0→1) heavy, (2→3) light — plus the mirror
    // on node 1 for symmetry. heavy = imbalance × light, volume fixed.
    let total = 4.0 * base_bytes;
    let light = total / (2.0 * (imbalance + 1.0));
    let heavy = imbalance * light;
    vec![
        Demand::new(0, 1, heavy),
        Demand::new(2, 3, light),
        Demand::new(topo.gpu(1, 0), topo.gpu(1, 1), heavy),
        Demand::new(topo.gpu(1, 2), topo.gpu(1, 3), light),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SinglePath;
    use crate::coordinator::NimbleRouter;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn batch_conserves_volume() {
        let t = Topology::paper();
        for imb in [1.0, 4.0, 16.0] {
            let batch = imbalanced_batch(&t, 8.0 * MB, imb);
            let total: f64 = batch.iter().map(|d| d.bytes).sum();
            assert!((total - 32.0 * MB).abs() < 1.0, "imb={imb}");
        }
    }

    /// The §I claim's direction: speedup grows with imbalance and
    /// message size; balanced batches show parity.
    #[test]
    fn speedup_grows_with_imbalance() {
        let t = Topology::paper();
        let params = FabricParams::default();
        let run = |imb: f64| {
            let batch = imbalanced_batch(&t, 64.0 * MB, imb);
            let mut base = SinglePath::new();
            let mut nim = NimbleRouter::default_for(&t);
            let a = sendrecv_batch(&t, &params, &mut base, &batch);
            let b = sendrecv_batch(&t, &params, &mut nim, &batch);
            a.makespan_s / b.makespan_s
        };
        let s1 = run(1.0);
        let s8 = run(8.0);
        // even "balanced" 2-streams-per-node leaves NVLink edges idle,
        // so NIMBLE may already win some; it must never be worse.
        assert!(s1 > 0.95, "regression on balanced batch: {s1}");
        assert!(s8 > s1, "no gain from imbalance: {s1} vs {s8}");
        assert!(s8 > 1.3, "imbalanced speedup too small: {s8}");
    }
}
