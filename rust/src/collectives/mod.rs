//! Communication operations built over the routing engines and the
//! fabric: the skew-sensitive ops NIMBLE orchestrates (All-to-Allv,
//! async Send/Recv) and the intrinsically balanced collectives that
//! keep their classic ring algorithms (§IV-E: "for these collectives,
//! NIMBLE is not involved in the routing orchestration").

pub mod alltoallv;
pub mod ring;
pub mod sendrecv;

pub use alltoallv::alltoallv;
pub use ring::{allgather, allreduce, reduce_scatter};
pub use sendrecv::sendrecv_batch;
