//! Balanced collectives on their classic ring algorithms (§IV-E).
//!
//! AllReduce = reduce-scatter + all-gather over a ring; each of the
//! 2(n−1) steps moves `bytes/n` between every adjacent rank pair
//! simultaneously. These schedules already use every link uniformly,
//! so NIMBLE leaves them alone — this module exists (a) for parity
//! experiments and (b) because a real framework ships them.

use crate::fabric::fluid::{Flow, FluidSim};
use crate::fabric::FabricParams;
use crate::topology::path::candidates;
use crate::topology::Topology;

/// Time (seconds) for one ring sweep step-set: `steps` sequential
/// steps, each moving `step_bytes` along every ring edge concurrently.
fn ring_steps_time(
    topo: &Topology,
    params: &FabricParams,
    steps: usize,
    step_bytes: f64,
) -> f64 {
    let n = topo.num_gpus();
    let mut total = 0.0;
    // every step has identical structure; simulate one and multiply
    // (ring edges don't change between steps)
    let flows: Vec<Flow> = (0..n)
        .map(|i| {
            let next = (i + 1) % n;
            let path = candidates(topo, i, next, false).remove(0);
            Flow::new(path, step_bytes)
        })
        .collect();
    let sim = FluidSim::new(topo, params.clone()).run(&flows);
    total += sim.makespan * steps as f64;
    total
}

/// Ring AllReduce completion time for `bytes` per rank.
pub fn allreduce(topo: &Topology, params: &FabricParams, bytes: f64) -> f64 {
    let n = topo.num_gpus();
    if n == 1 {
        return 0.0;
    }
    ring_steps_time(topo, params, 2 * (n - 1), bytes / n as f64)
}

/// Ring ReduceScatter: (n−1) steps of bytes/n.
pub fn reduce_scatter(topo: &Topology, params: &FabricParams, bytes: f64) -> f64 {
    let n = topo.num_gpus();
    if n == 1 {
        return 0.0;
    }
    ring_steps_time(topo, params, n - 1, bytes / n as f64)
}

/// Ring AllGather: (n−1) steps of bytes/n.
pub fn allgather(topo: &Topology, params: &FabricParams, bytes: f64) -> f64 {
    reduce_scatter(topo, params, bytes) // identical schedule shape
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn allreduce_is_two_phases() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let ar = allreduce(&t, &p, 256.0 * MB);
        let rs = reduce_scatter(&t, &p, 256.0 * MB);
        let ag = allgather(&t, &p, 256.0 * MB);
        assert!((ar - (rs + ag)).abs() / ar < 1e-9);
    }

    #[test]
    fn bandwidth_approaches_ring_bound() {
        let t = Topology::paper();
        let p = FabricParams::default();
        let bytes = 512.0 * MB;
        let time = allreduce(&t, &p, bytes);
        // algorithm bandwidth = bytes·2(n−1)/n / time; the ring crosses
        // the inter-node rail (45.1 GB/s) twice, so the busbw bound is
        // the rail rate.
        let busbw = 2.0 * (8.0 - 1.0) / 8.0 * bytes / time / 1e9;
        assert!(busbw <= 45.1 + 0.1, "busbw={busbw}");
        assert!(busbw > 30.0, "busbw={busbw} too far below the rail bound");
    }

    #[test]
    fn zero_bytes_is_near_zero_time() {
        let t = Topology::paper();
        let p = FabricParams::default();
        // latency-only floor
        let time = allreduce(&t, &p, 8.0);
        assert!(time < 1e-3);
    }
}
