//! All-to-Allv: each rank sends a variable-sized message to every
//! peer. The demand matrix is routed by whichever engine is under
//! test; this is the primitive of Fig 7 and the dispatch/combine
//! phases of Fig 8.

use crate::baselines::{run_round, Router};
use crate::fabric::FabricParams;
use crate::metrics::CommReport;
use crate::planner::Demand;
use crate::topology::Topology;

/// Run one All-to-Allv round from an explicit byte matrix
/// (`matrix[s][d]`, diagonal ignored).
pub fn alltoallv(
    topo: &Topology,
    params: &FabricParams,
    router: &mut dyn Router,
    matrix: &[Vec<f64>],
) -> CommReport {
    let n = topo.num_gpus();
    assert_eq!(matrix.len(), n, "matrix must be num_gpus × num_gpus");
    let mut demands = Vec::new();
    for (s, row) in matrix.iter().enumerate() {
        assert_eq!(row.len(), n);
        for (d, &b) in row.iter().enumerate() {
            if s != d && b > 0.0 {
                demands.push(Demand::new(s, d, b));
            }
        }
    }
    run_round(topo, params, router, &demands)
}

/// Convenience: run from a demand list (as produced by the workload
/// generators).
pub fn alltoallv_demands(
    topo: &Topology,
    params: &FabricParams,
    router: &mut dyn Router,
    demands: &[Demand],
) -> CommReport {
    run_round(topo, params, router, demands)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::NcclLike;
    use crate::coordinator::NimbleRouter;
    use crate::workloads::skew::hotspot_alltoallv;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn matrix_and_demand_forms_agree() {
        let t = Topology::paper();
        let params = FabricParams::default();
        let demands = hotspot_alltoallv(&t, 16.0 * MB, 0.5, 2);
        let mut m = vec![vec![0.0; 8]; 8];
        for d in &demands {
            m[d.src][d.dst] = d.bytes;
        }
        let mut e1 = NcclLike::new();
        let mut e2 = NcclLike::new();
        let r1 = alltoallv(&t, &params, &mut e1, &m);
        let r2 = alltoallv_demands(&t, &params, &mut e2, &demands);
        assert!((r1.makespan_s - r2.makespan_s).abs() < 1e-12);
    }

    /// Monotonicity: NIMBLE's advantage grows with the hotspot ratio
    /// (the Fig 7 trend).
    #[test]
    fn speedup_grows_with_skew() {
        let t = Topology::paper();
        let params = FabricParams::default();
        let mut speedups = Vec::new();
        for ratio in [0.2, 0.5, 0.8] {
            let demands = hotspot_alltoallv(&t, 64.0 * MB, ratio, 4);
            let mut nccl = NcclLike::new();
            let mut nim = NimbleRouter::default_for(&t);
            let a = alltoallv_demands(&t, &params, &mut nccl, &demands);
            let b = alltoallv_demands(&t, &params, &mut nim, &demands);
            speedups.push(a.makespan_s / b.makespan_s);
        }
        assert!(
            speedups[0] <= speedups[1] + 0.15 && speedups[1] <= speedups[2] + 0.15,
            "speedups not increasing: {speedups:?}"
        );
        assert!(speedups[2] > 1.5, "high-skew speedup too small: {speedups:?}");
    }
}
