//! Microbenchmarks of the L3 hot path: Algorithm 1 planning across
//! workload shapes/sizes, the fluid simulator's rate solver, and the
//! chunk-pipeline DP. These are the perf targets of DESIGN.md §4.

use nimble::exp::MB;
use nimble::fabric::fluid::{Flow, FluidSim};
use nimble::fabric::pipeline::PipelineModel;
use nimble::fabric::{FabricParams, XferMode};
use nimble::planner::{Demand, Planner, PlannerCfg};
use nimble::topology::path::candidates;
use nimble::topology::Topology;
use nimble::util::bench::{bench, header};
use nimble::workloads::skew::hotspot_alltoallv;
use nimble::workloads::stencil::stencil_1d;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("{}", header());

    // planner on the Table-I stencil (the paper's 0.032–0.048 ms row)
    let demands = stencil_1d(&topo, 64.0 * MB);
    let r = bench("plan: stencil 14 pairs @64MB", 0.5, || {
        let mut p = Planner::new(&topo, PlannerCfg::default());
        std::hint::black_box(p.plan(&demands));
    });
    println!("{}", r.row());

    // planner on the skewed all-to-allv (56 pairs)
    let demands = hotspot_alltoallv(&topo, 64.0 * MB, 0.9, 4);
    let r = bench("plan: skewed a2av 56 pairs @64MB", 0.5, || {
        let mut p = Planner::new(&topo, PlannerCfg::default());
        std::hint::black_box(p.plan(&demands));
    });
    println!("{}", r.row());

    // planner with reused candidate cache (execution-time re-planning)
    let mut warm = Planner::new(&topo, PlannerCfg::default());
    let r = bench("plan: skewed a2av, warm planner", 0.5, || {
        std::hint::black_box(warm.plan(&demands));
    });
    println!("{}", r.row());

    // single large pair (the `nimble plan` path)
    let one = vec![Demand::new(0, 4, 256.0 * MB)];
    let r = bench("plan: single pair @256MB", 0.5, || {
        let mut p = Planner::new(&topo, PlannerCfg::default());
        std::hint::black_box(p.plan(&one));
    });
    println!("{}", r.row());

    // fluid simulator on the skewed a2av flow set
    let mut router = nimble::coordinator::NimbleRouter::default_for(&topo);
    let flows: Vec<Flow> = {
        use nimble::baselines::Router;
        router
            .route(&topo, &demands)
            .into_iter()
            .map(|(p, b)| Flow::new(p, b))
            .collect()
    };
    let sim = FluidSim::new(&topo, params.clone());
    let r = bench(&format!("fluid sim: {} flows", flows.len()), 0.5, || {
        std::hint::black_box(sim.run(&flows));
    });
    println!("{}", r.row());

    // chunk pipeline DP on a 3-hop 256 MB transfer
    let m = PipelineModel::new(&topo, params.clone());
    let path = candidates(&topo, 1, 6, true).remove(3);
    let r = bench("pipeline DP: 3-hop 256MB (512 chunks)", 0.5, || {
        std::hint::black_box(m.transfer(&path, 256.0 * MB, XferMode::Kernel));
    });
    println!("{}", r.row());
}
