//! Cluster-scale hot-path bench: sweep {1, 2, 4, 8} nodes of the
//! skewed All-to-Allv and report simulated events/sec for the
//! incremental water-filler vs the from-scratch reference solver
//! (bit-identical trajectories, so the ratio is a pure solver
//! speedup), plus planner time and goodput.
//!
//! Besides the human-readable table, every config emits one
//! machine-readable JSON line (`{"exp":"scale","nodes":…}`) so the
//! perf trajectory can be tracked across PRs by grepping bench logs.

use nimble::exp::scale;
use nimble::exp::MB;
use nimble::fabric::FabricParams;
use nimble::planner::PlannerCfg;

fn main() {
    let payload = 64.0 * MB;
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get().min(8));
    let params = FabricParams::default();
    let pcfg = PlannerCfg { threads, ..PlannerCfg::default() };
    println!("== scale sweep: skewed All-to-Allv, {:.0} MB/rank ==", payload / MB);
    let rows =
        scale::sweep(&[1, 2, 4, 8], payload, &params, &pcfg, true, scale::ScaleTopo::Flat);
    println!("{}", scale::render(&rows, payload, threads));
    // machine-readable perf trajectory (one line per config)
    for r in &rows {
        println!("{}", r.json_line());
    }
    // the acceptance gate this PR ships under: ≥5x events/sec at 4
    // nodes over the pre-PR solver, with identical trajectories
    let four = rows.iter().find(|r| r.nodes == 4).expect("4-node row");
    let speedup = four.speedup().expect("reference run present");
    println!(
        "4-node solver speedup: {speedup:.2}x ({} events, {:.0} ev/s incremental vs {:.0} ev/s reference)",
        four.events,
        four.events_per_sec(),
        four.reference_events_per_sec().unwrap_or(0.0),
    );
    assert!(
        speedup >= 5.0,
        "hot-path regression: incremental solver only {speedup:.2}x over reference at 4 nodes"
    );
}
