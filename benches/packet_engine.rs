//! Packet-engine scheduler bench: fly the planned cross-pod skewed
//! All-to-Allv from `nimble scale --topo fat-tree` on the chunk-granular
//! DES under both event schedulers — the hierarchical timing wheel and
//! the binary-heap oracle it replaced — and record events/sec for each.
//!
//! The two runs are asserted bit-identical (event count, makespan bits,
//! per-flow finish bits, link bytes, tail samples) before any timing is
//! reported, so the ratio is a pure scheduler speedup on the same event
//! stream. At the 64-node point the ratio is gated at ≥5x — the perf
//! target the event-core rebuild was sized for. CI runs the
//! noise-tolerant twin of this gate (`nimble scale --check`, 1.5x
//! floor); this harness tracks the real trajectory across PRs.
//!
//! Like `benches/scale_sweep.rs`, every point emits one machine-readable
//! JSON line (`{"exp":"packet_engine",...}`).

use nimble::exp::scale::{check_packet_engine, ScaleTopo};
use nimble::exp::MB;
use nimble::fabric::FabricParams;
use nimble::planner::PlannerCfg;

/// Wheel-over-heap events/sec floor asserted at the 64-node point.
const SPEEDUP_FLOOR: f64 = 5.0;

fn main() {
    let params = FabricParams::default();
    let pcfg = PlannerCfg::default();
    let payload = 64.0 * MB;
    println!(
        "== packet engine bench: timing wheel vs heap oracle, fat-tree A2A, {:.0} MB/rank ==",
        payload / MB
    );
    for (nodes, floor) in [(16usize, None), (64, Some(SPEEDUP_FLOOR))] {
        let smoke = check_packet_engine(
            nodes,
            payload,
            &params,
            &pcfg,
            ScaleTopo::FatTree { oversub: 2.0 },
            floor,
        );
        println!("{}", smoke.json_line());
    }
    println!(
        "packet engine bench done (wheel bit-identical to heap; \
         >= {SPEEDUP_FLOOR:.0}x floor asserted at 64 nodes)"
    );
}
