//! Packet-engine scheduler bench: fly the planned cross-pod skewed
//! All-to-Allv from `nimble scale --topo fat-tree` on the chunk-granular
//! DES under both event schedulers — the hierarchical timing wheel and
//! the binary-heap oracle it replaced — and record events/sec for each.
//!
//! The two runs are asserted bit-identical (event count, makespan bits,
//! per-flow finish bits, link bytes, tail samples) before any timing is
//! reported, so the ratio is a pure scheduler speedup on the same event
//! stream. At the 64-node point the ratio is gated at ≥5x — the perf
//! target the event-core rebuild was sized for. CI runs the
//! noise-tolerant twin of this gate (`nimble scale --check`, 1.5x
//! floor); this harness tracks the real trajectory across PRs.
//!
//! Since the telemetry subsystem landed, this harness also gates the
//! trace overhead: flying the identical replanned packet run with an
//! enabled [`Recorder`] (per-epoch snapshots, decision audits, the
//! summary record — and, since the attribution engine landed, the
//! per-window blame decomposition plus the end-of-run tail histograms)
//! must cost at most 5% wall clock over the disabled no-op recorder —
//! and must reproduce the makespan bit-for-bit, the observer-purity
//! contract of DESIGN.md §15/§16. The enabled arm is asserted to have
//! actually emitted `attribution` and `histogram` records, so the gate
//! cannot silently stop covering the attribution path.
//!
//! Like `benches/scale_sweep.rs`, every point emits one machine-readable
//! JSON line (`{"exp":"packet_engine",...}`).

use nimble::coordinator::ReplanExecutor;
use nimble::exp::scale::{check_packet_engine, ScaleTopo};
use nimble::exp::MB;
use nimble::fabric::{BackendKind, FabricParams};
use nimble::planner::{Planner, PlannerCfg, ReplanCfg};
use nimble::telemetry::Recorder;
use nimble::topology::Topology;
use nimble::util::json::{json_line, Json};
use nimble::workloads::skew::hotspot_alltoallv;
use std::time::Instant;

/// Wheel-over-heap events/sec floor asserted at the 64-node point.
const SPEEDUP_FLOOR: f64 = 5.0;

/// Wall-clock ceiling on the enabled-recorder overhead (fractional).
const TELEMETRY_OVERHEAD_CEILING: f64 = 0.05;

/// Repetitions per arm; min-of-N absorbs shared-machine noise so the
/// 5% gate measures the recorder, not the scheduler jitter.
const OVERHEAD_REPS: usize = 7;

/// The telemetry-overhead gate: the replanned packet run that carries
/// the densest instrumentation (an epoch snapshot every cadence, plus
/// planner audits and the summary) is flown with the recorder off and
/// on; the enabled arm must stay within [`TELEMETRY_OVERHEAD_CEILING`]
/// of the disabled arm's wall clock and reproduce its makespan bits.
fn telemetry_overhead_gate() {
    let topo = Topology::paper();
    let demands = hotspot_alltoallv(&topo, 64.0 * MB, 0.7, topo.gpu(1, 0));
    let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
    let params = FabricParams { backend: BackendKind::Packet, ..FabricParams::default() };
    let rcfg = ReplanCfg { enable: true, cadence_s: 2.0e-4, margin: 0.1, ..ReplanCfg::default() };
    let fly = |rec: Recorder| {
        let t = Instant::now();
        let run = ReplanExecutor::new(&topo, params.clone(), PlannerCfg::default(), rcfg.clone())
            .with_recorder(rec)
            .execute(&plan, &demands);
        (t.elapsed().as_secs_f64(), run.report.makespan_s)
    };
    // warm-up pass per arm, then interleaved timed passes
    let (_, makespan_off) = fly(Recorder::disabled());
    let rec = Recorder::enabled();
    let (_, makespan_on) = fly(rec.clone());
    let records = rec.len();
    assert!(records > 0, "enabled recorder captured nothing");
    let kind_count = |k: &str| {
        rec.lines().iter().filter(|l| l.get("kind").as_str() == Some(k)).count()
    };
    let attributions = kind_count("attribution");
    let histograms = kind_count("histogram");
    assert!(
        attributions > 0,
        "recorder-on run emitted no attribution records: the overhead \
         gate is no longer exercising the blame decomposition"
    );
    assert!(
        histograms > 0,
        "recorder-on packet run emitted no tail histogram records"
    );
    assert_eq!(
        makespan_off.to_bits(),
        makespan_on.to_bits(),
        "tracing (with attribution sampling) changed the simulated makespan"
    );
    let (mut off, mut on) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..OVERHEAD_REPS {
        off = off.min(fly(Recorder::disabled()).0);
        on = on.min(fly(Recorder::enabled()).0);
    }
    let overhead = on / off.max(1e-12) - 1.0;
    let line = json_line(
        "packet_engine.telemetry",
        vec![
            ("records", Json::num(records as f64)),
            ("attributions", Json::num(attributions as f64)),
            ("histograms", Json::num(histograms as f64)),
            ("off_ms", Json::num(off * 1e3)),
            ("on_ms", Json::num(on * 1e3)),
            ("overhead_frac", Json::num(overhead)),
        ],
    );
    println!("{line}");
    assert!(
        overhead <= TELEMETRY_OVERHEAD_CEILING,
        "telemetry overhead {:.1}% exceeds the {:.0}% ceiling (off {:.3} ms, on {:.3} ms)",
        overhead * 1e2,
        TELEMETRY_OVERHEAD_CEILING * 1e2,
        off * 1e3,
        on * 1e3
    );
}

fn main() {
    let params = FabricParams::default();
    let pcfg = PlannerCfg::default();
    let payload = 64.0 * MB;
    println!(
        "== packet engine bench: timing wheel vs heap oracle, fat-tree A2A, {:.0} MB/rank ==",
        payload / MB
    );
    for (nodes, floor) in [(16usize, None), (64, Some(SPEEDUP_FLOOR))] {
        let smoke = check_packet_engine(
            nodes,
            payload,
            &params,
            &pcfg,
            ScaleTopo::FatTree { oversub: 2.0 },
            floor,
        );
        println!("{}", smoke.json_line());
    }
    telemetry_overhead_gate();
    println!(
        "packet engine bench done (wheel bit-identical to heap; \
         >= {SPEEDUP_FLOOR:.0}x floor asserted at 64 nodes; telemetry \
         overhead <= {:.0}%)",
        TELEMETRY_OVERHEAD_CEILING * 1e2
    );
}
