//! Bench: asynchronous Send/Recv imbalance sweep (§I highlight:
//! 1.15–2.3× at 8 MB, up to 3.4× at 256 MB as imbalance grows).

use nimble::exp::sendrecv;
use nimble::fabric::FabricParams;
use nimble::topology::Topology;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("{}", sendrecv::render(&topo, &params));
}
