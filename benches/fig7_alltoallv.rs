//! Bench: Fig 7 — skewed All-to-Allv hotspot-ratio sweep, NCCL vs
//! OpenMPI vs NIMBLE, at the paper's large-message regime plus a
//! small-message regime where copy-engine baselines shine.

use nimble::exp::{fig7, MB};
use nimble::fabric::FabricParams;
use nimble::topology::Topology;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    for payload_mb in [64.0, 8.0, 0.25] {
        println!("{}", fig7::render(&topo, &params, payload_mb * MB));
        println!();
    }
    println!("(paper reference: ≥5× vs NCCL at hotspot ≥0.7 with large messages;");
    println!(" parity — OpenMPI slightly ahead — at small sizes / mild skew)");
}
