//! Tiered-fabric scale bench: fly the seeded cross-pod skewed
//! All-to-Allv (per-rank hot peers half the cluster away — the skew
//! that stresses the oversubscribed core, DESIGN.md §12) on two-tier
//! fat-trees and report planner/simulator hot-path numbers plus the
//! planned-vs-ECMP goodput ratio — the adversary comparison the
//! tiered generalization ships under.
//!
//! Every row emits one machine-readable JSON line
//! (`{"exp":"scale","topo":"fat-tree",…}`) so the trajectory is
//! trackable across PRs by grepping bench logs.

use nimble::exp::scale;
use nimble::exp::MB;
use nimble::fabric::FabricParams;
use nimble::planner::PlannerCfg;

fn main() {
    let payload = 16.0 * MB;
    let oversub = 2.0;
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get().min(8));
    let params = FabricParams::default();
    let pcfg = PlannerCfg { threads, ..PlannerCfg::default() };
    println!(
        "== fat-tree scale sweep: skewed All-to-Allv, {:.0} MB/rank, {oversub}:1 core ==",
        payload / MB
    );
    let rows = scale::sweep(
        &[8, 16, 32, 64],
        payload,
        &params,
        &pcfg,
        false,
        scale::ScaleTopo::FatTree { oversub },
    );
    println!("{}", scale::render(&rows, payload, threads));
    for r in &rows {
        println!("{}", r.json_line());
    }
    // the acceptance gate of the tiered generalization: at 64 nodes the
    // planned multi-path routing must not lose to hash striping
    let big = rows.iter().find(|r| r.nodes == 64).expect("64-node row");
    let ratio = big.planned_over_ecmp().expect("ecmp run present");
    println!(
        "64-node planned vs ECMP: {ratio:.2}x ({:.1} vs {:.1} GB/s, core uplink util {:.2})",
        big.goodput_gbps,
        big.ecmp_goodput_gbps.unwrap_or(0.0),
        big.core_uplink_util.unwrap_or(0.0),
    );
    assert!(
        ratio >= 1.0,
        "tiered regression: planned routing only {ratio:.2}x of ECMP at 64 nodes"
    );
}
