//! Multi-tenant serve bench: fly the default seeded 8-job stream on
//! the shared fluid fabric, joint orchestrator vs independent per-job
//! plans, and report wall-clock serving throughput plus the
//! quality-of-service metrics.
//!
//! Like `benches/scale_sweep.rs`, every arm emits one machine-readable
//! JSON line (`{"exp":"serve_tenants",...}`) so the orchestrator's
//! perf trajectory is trackable across PRs: jobs/sec (wall), aggregate
//! goodput, weighted fairness, replans, preemptions, sim events.

use nimble::exp::serve::run_arm;
use nimble::fabric::FabricParams;
use nimble::orchestrator::TenancyCfg;
use nimble::planner::{PlannerCfg, ReplanCfg};
use nimble::topology::Topology;
use nimble::util::json::{json_line, Json};
use std::time::Instant;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    let pcfg = PlannerCfg::default();
    let rcfg = ReplanCfg::default();
    println!("== serve bench: multi-tenant orchestrator on the shared fluid fabric ==");
    for joint in [true, false] {
        let tcfg = TenancyCfg { joint, ..TenancyCfg::default() };
        // warm-up pass, then the measured pass
        let _ = run_arm(&topo, &params, &pcfg, &rcfg, &tcfg);
        let t = Instant::now();
        let run = run_arm(&topo, &params, &pcfg, &rcfg, &tcfg);
        let wall = t.elapsed().as_secs_f64();
        let line = json_line(
            "serve_tenants",
            vec![
                ("arm", Json::str(if joint { "joint" } else { "independent" })),
                ("jobs", Json::num(run.tenants.len() as f64)),
                ("jobs_per_sec", Json::num(run.tenants.len() as f64 / wall.max(1e-12))),
                ("wall_ms", Json::num(wall * 1e3)),
                ("makespan_ms", Json::num(run.makespan_s * 1e3)),
                ("aggregate_goodput_gbps", Json::num(run.aggregate_goodput_gbps)),
                ("weighted_fairness", Json::num(run.weighted_fairness)),
                ("replans", Json::num(run.replans as f64)),
                ("preemptions", Json::num(run.preemptions as f64)),
                ("sim_events", Json::num(run.sim_events as f64)),
            ],
        );
        println!("{line}");
        // per-tenant goodput lines (the fairness trajectory)
        for t in &run.tenants {
            let line = json_line(
                "serve_tenants.tenant",
                vec![
                    ("arm", Json::str(if joint { "joint" } else { "independent" })),
                    ("tenant", Json::num(t.id as f64)),
                    ("kind", Json::str(t.kind.name())),
                    ("weight", Json::num(t.weight)),
                    ("goodput_gbps", Json::num(t.goodput_gbps)),
                    ("p99_lat_ms", Json::num(t.p99_lat_s * 1e3)),
                ],
            );
            println!("{line}");
        }
    }
    println!(
        "serve bench done (acceptance asserted by `nimble serve --jobs 8 --check`)"
    );
}
