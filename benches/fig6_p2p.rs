//! Bench: Fig 6 — all four panels of the point-to-point multi-path
//! study (intra 1/2/3 paths, inter 1/2/4 NICs, both forwarding
//! overhead panels), plus wall-clock of the underlying simulators.

use nimble::exp::fig6;
use nimble::fabric::FabricParams;
use nimble::topology::Topology;
use nimble::util::bench::{bench, header};

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("{}", fig6::render(&topo, &params, "all"));

    println!("{}", header());
    let r = bench("fig6a full sweep (fluid sim)", 0.5, || {
        let _ = fig6::fig6a(&topo, &params);
    });
    println!("{}", r.row());
    let r = bench("fig6c full sweep (chunk pipeline)", 0.5, || {
        let _ = fig6::fig6c(&topo, &params);
    });
    println!("{}", r.row());
}
