//! Packet-backend throughput bench: fly the 4-node skewed All-to-Allv
//! from `nimble scale` (same jitter seed, same planned routing) on the
//! packet-level discrete-event simulator and report events/sec, plus
//! the fluid-engine goodput on the identical flow set as the
//! cross-validation ratio.
//!
//! Like `benches/scale_sweep.rs`, every config emits one
//! machine-readable JSON line (`{"exp":"xcheck_backend",...}`) so the
//! packet backend's perf trajectory is trackable across PRs.

use nimble::exp::scale::{plan_flows, scale_demands};
use nimble::exp::MB;
use nimble::fabric::fluid::FluidSim;
use nimble::fabric::packet::PacketSim;
use nimble::fabric::FabricParams;
use nimble::planner::{Planner, PlannerCfg};
use nimble::topology::Topology;
use nimble::util::json::{json_line, Json};
use std::time::Instant;

fn main() {
    let payload = 64.0 * MB;
    let params = FabricParams::default();
    println!(
        "== xcheck backend bench: packet-level DES, skewed All-to-Allv, {:.0} MB/rank ==",
        payload / MB
    );
    for nodes in [1usize, 2, 4] {
        let topo = Topology::cluster(nodes);
        let demands = scale_demands(&topo, payload);
        let plan = Planner::new(&topo, PlannerCfg::default()).plan(&demands);
        let flows = plan_flows(&plan);
        let payload_total: f64 = demands.iter().map(|d| d.bytes).sum();

        let mut sim = PacketSim::new(&topo, params.clone(), &flows);
        let t = Instant::now();
        sim.run_to_completion().expect("fault-free bench cannot stall");
        let wall = t.elapsed().as_secs_f64();
        let r = sim.result();
        let tail = sim.tail();
        let goodput = payload_total / r.makespan.max(1e-12) / 1e9;

        let fluid = FluidSim::new(&topo, params.clone()).run(&flows);
        let fluid_goodput = payload_total / fluid.makespan.max(1e-12) / 1e9;

        let line = json_line(
            "xcheck_backend",
            vec![
                ("nodes", Json::num(nodes as f64)),
                ("flows", Json::num(flows.len() as f64)),
                ("chunks", Json::num(tail.delivered_chunks as f64)),
                ("events", Json::num(sim.events() as f64)),
                ("events_per_sec", Json::num(sim.events() as f64 / wall.max(1e-12))),
                ("sim_ms", Json::num(wall * 1e3)),
                ("goodput_gbps", Json::num(goodput)),
                ("fluid_goodput_gbps", Json::num(fluid_goodput)),
                ("ratio_vs_fluid", Json::num(goodput / fluid_goodput.max(1e-12))),
                ("p99_us", Json::num(tail.sojourn.quantile_s(99.0) * 1e6)),
            ],
        );
        println!("{line}");
    }
    println!("xcheck backend bench done (agreement asserted by `nimble xcheck --check`)");
}
