//! Bench: Table I — planner (Algorithm 1) time vs communication time
//! on the 1-D stencil, intra-node and inter-node, sizes 16–256 MB.
//! Regenerates the paper's table rows (paper: algo 0.032–0.048 ms).

use nimble::exp::table1;
use nimble::fabric::FabricParams;
use nimble::topology::Topology;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("{}", table1::render(&topo, &params, 19));
    println!("(paper reference: intra algo 0.0321–0.0363 ms / comm 0.197–2.046 ms;");
    println!(" inter algo 0.0325–0.0480 ms / comm 0.486–6.539 ms)");
}
