//! Bench of the execution-time re-planning loop: per-epoch overhead
//! (monitor sample + incremental replan decision) and the end-to-end
//! static vs re-planned round on the phase-shifting hot-row workload.

use nimble::coordinator::replan::ReplanExecutor;
use nimble::exp::MB;
use nimble::fabric::FabricParams;
use nimble::planner::{Planner, PlannerCfg, ReplanCfg};
use nimble::topology::Topology;
use nimble::util::bench::{bench, header};
use nimble::workloads::dynamic::PhasedHotRows;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("{}", header());

    let sched = PhasedHotRows::paper_default(&topo, 64.0 * MB);
    let d0 = sched.demands_at(&topo, 0);
    let d1 = sched.demands_at(&topo, 1);
    let incumbent = Planner::new(&topo, PlannerCfg::default()).plan(&d0);
    let enabled =
        ReplanCfg { enable: true, cadence_s: 5.0e-4, margin: 0.1, ..ReplanCfg::default() };

    // the per-epoch decision alone (the loop's hot path): observed
    // loads match the plan, so this measures the no-op fast path
    let observed = incumbent.link_load.clone();
    let mut warm = Planner::new(&topo, PlannerCfg::default());
    let r = bench("replan decision: matched (no-op)", 0.5, || {
        std::hint::black_box(warm.replan(&incumbent, &observed, &d0, &enabled));
    });
    println!("{}", r.row());

    // the decision when the hot row shifted (challenger computed)
    let r = bench("replan decision: shifted hot row", 0.5, || {
        std::hint::black_box(warm.replan(&incumbent, &observed, &d1, &enabled));
    });
    println!("{}", r.row());

    // one full static round (engine only, no epochs)
    let mut stat = ReplanExecutor::new(
        &topo,
        params.clone(),
        PlannerCfg::default(),
        ReplanCfg::default(),
    );
    let r = bench("round: static plan (stale)", 0.5, || {
        std::hint::black_box(stat.execute(&incumbent, &d1));
    });
    println!("{}", r.row());

    // one full re-planned round (epochs + preemption + reroute)
    let mut dynex =
        ReplanExecutor::new(&topo, params, PlannerCfg::default(), enabled);
    let r = bench("round: monitor+replan+reroute loop", 0.5, || {
        std::hint::black_box(dynex.execute(&incumbent, &d1));
    });
    println!("{}", r.row());
}
