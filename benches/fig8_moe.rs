//! Bench: Fig 8 — MoE end-to-end step latency breakdown
//! (dispatch/compute/combine) over tokens × hotspot, NCCL vs NIMBLE.

use nimble::exp::fig8;
use nimble::fabric::FabricParams;
use nimble::topology::Topology;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("{}", fig8::render(&topo, &params));
    let rows = fig8::sweep(&topo, &params);
    for &h in &fig8::HOTSPOTS {
        let v: Vec<f64> =
            rows.iter().filter(|r| r.hotspot == h).map(|r| r.speedup()).collect();
        let avg = v.iter().sum::<f64>() / v.len() as f64;
        let peak = v.iter().cloned().fold(0.0, f64::max);
        println!("hotspot {h}: avg speedup {avg:.3}, peak {peak:.3}");
    }
    println!("(paper reference: avg 1.13×@0.4 → 1.26×@0.9, peak 1.35× @16K tokens)");
}
