//! Fault-recovery bench: time-to-recover and goodput retention per
//! fault scenario (`nimble faults` arms) on the flat testbed and a
//! fat-tree cluster, plus the wall cost of flying the faulted
//! epoch-driven loop.
//!
//! Like `benches/scale_sweep.rs`, every (topo, scenario, arm) emits
//! one machine-readable JSON line (`{"exp":"fault_recovery",...}`) so
//! the recovery trajectory is trackable across PRs. The
//! replanned-beats-static retention floor is asserted here too: a
//! perf-tracking run must not silently ship a recovery regression.

use nimble::exp::faults::{scenario_rows, CADENCE_S};
use nimble::fabric::{FabricParams, Scenario, ScenarioParams};
use nimble::planner::PlannerCfg;
use nimble::topology::Topology;
use nimble::util::json::{json_line, Json};
use std::time::Instant;

fn main() {
    let params = FabricParams::default();
    let pcfg = PlannerCfg::default();
    let fparams = ScenarioParams::default();
    println!(
        "== fault recovery bench: {} scenarios x (static | replan | ecmp), epoch {:.2} ms ==",
        Scenario::all().len(),
        CADENCE_S * 1e3
    );
    let mb = 1024.0 * 1024.0;
    let flat = Topology::paper();
    let fat = Topology::fat_tree(4, 2.0);
    for (label, topo, per_rank) in
        [("flat", &flat, 96.0 * mb), ("fat-tree", &fat, 24.0 * mb)]
    {
        let t = Instant::now();
        let (clean, rows) = scenario_rows(
            label,
            topo,
            per_rank,
            &params,
            &pcfg,
            &fparams,
            &Scenario::all(),
            true,
        );
        let wall = t.elapsed().as_secs_f64();
        for r in &rows {
            let line = json_line(
                "fault_recovery",
                vec![
                    ("topo", Json::str(r.topo)),
                    ("scenario", Json::str(r.scenario.label())),
                    ("arm", Json::str(r.arm)),
                    ("goodput_gbps", Json::num(r.goodput_gbps)),
                    ("clean_gbps", Json::num(clean.goodput_gbps)),
                    ("retention", Json::num(r.retention)),
                    // -1: the arm never re-reached 90% of steady state
                    (
                        "ttr_epochs",
                        Json::num(r.ttr_epochs.map_or(-1.0, |k| k as f64)),
                    ),
                    (
                        "ttr_ms",
                        Json::num(
                            r.ttr_epochs.map_or(-1.0, |k| k as f64 * CADENCE_S * 1e3),
                        ),
                    ),
                    ("replans", Json::num(r.replans as f64)),
                    ("preemptions", Json::num(r.preemptions as f64)),
                    ("wall_s_all_arms", Json::num(wall)),
                ],
            );
            println!("{line}");
        }
        // the recovery floor: on every scenario the replanned arm must
        // retain at least as much goodput as the frozen static plan
        // (0.1% slack: no-escape scenarios legitimately tie)
        for sc in Scenario::all() {
            let get = |arm: &str| {
                rows.iter()
                    .find(|r| {
                        r.arm == arm && r.scenario.label() == sc.label()
                    })
                    .expect("arm present")
            };
            let (st, re) = (get("static"), get("replan"));
            assert!(
                re.retention >= st.retention * 0.999,
                "{label} {}: replanned retention {:.3} fell below static {:.3}",
                sc.label(),
                re.retention,
                st.retention
            );
        }
    }
    println!("fault recovery bench done (gates enforced by `nimble faults --check`)");
}
