"""L2: the JAX compute graphs NIMBLE's coordinator executes via PJRT.

Three graph families, all AOT-lowered to HLO text by aot.py:

* ``expert_ffn`` — one expert's FFN over a token bucket, calling the
  L1 Pallas kernel (the Fig 8 compute phase).
* ``moe_block_fwd`` — gating + all experts + weighted combine (both
  Pallas kernels), the quickstart's single-device MoE block.
* ``train_step`` — a tiny MoE-transformer language model's fused
  forward/backward/SGD step for the end-to-end example
  (examples/moe_e2e.rs). The training graph uses the pure-jnp FFN
  (mathematically identical to the kernel — pytest asserts so)
  because Pallas interpret-mode has no registered VJP; the inference
  graphs exercise the Pallas kernels.

Everything here is build-time only; nothing imports at runtime.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels.combine import combine_topk
from compile.kernels.moe_ffn import moe_ffn
from compile.kernels.ref import moe_ffn_ref


# ---------------------------------------------------------------------------
# Inference graphs (Pallas kernels)
# ---------------------------------------------------------------------------

def expert_ffn(x, w1, w2):
    """One expert FFN over a token bucket (L1 Pallas kernel)."""
    return moe_ffn(x, w1, w2, block_m=128, block_f=512)


def moe_block_fwd(x, wg, w1s, w2s):
    """Single-device MoE block: softmax gating over E experts, every
    expert runs the Pallas FFN, outputs are gate-weighted and combined
    with the Pallas combine kernel (soft-mixture form: exercises both
    kernels and is the dense reference the EP pipeline must match).

    x: (T, D); wg: (D, E); w1s: (E, D, F); w2s: (E, F, D) → (T, D)
    """
    e = wg.shape[1]
    gates = jax.nn.softmax((x.astype(jnp.float32) @ wg.astype(jnp.float32)), axis=-1)
    ys = jnp.stack([expert_ffn(x, w1s[i], w2s[i]) for i in range(e)])  # (E,T,D)
    return combine_topk(ys, gates)


# ---------------------------------------------------------------------------
# Training model (tiny MoE-transformer LM)
# ---------------------------------------------------------------------------

class LmConfig(NamedTuple):
    vocab: int = 256
    d_model: int = 128
    d_ff: int = 512
    n_experts: int = 4
    n_layers: int = 2
    seq: int = 128
    batch: int = 8
    lr: float = 0.05

    @property
    def param_specs(self):
        """Canonical (name, shape) order — the AOT flattening contract
        shared with the rust runtime via manifest.json."""
        c = self
        specs = [("embed", (c.vocab, c.d_model))]
        for l in range(c.n_layers):
            specs += [
                (f"l{l}.wq", (c.d_model, c.d_model)),
                (f"l{l}.wk", (c.d_model, c.d_model)),
                (f"l{l}.wv", (c.d_model, c.d_model)),
                (f"l{l}.wo", (c.d_model, c.d_model)),
                (f"l{l}.wg", (c.d_model, c.n_experts)),
                (f"l{l}.w1", (c.n_experts, c.d_model, c.d_ff)),
                (f"l{l}.w2", (c.n_experts, c.d_ff, c.d_model)),
            ]
        specs.append(("unembed", (c.d_model, c.vocab)))
        return specs

    def param_count(self):
        import math
        return sum(math.prod(s) for _, s in self.param_specs)


def init_params(key, cfg: LmConfig):
    """He-ish init, returned as the canonical flat list of arrays."""
    params = []
    for name, shape in cfg.param_specs:
        key, sub = jax.random.split(key)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        params.append(jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in))
    return params


def _causal_attention(x, wq, wk, wv, wo):
    """Single-head causal self-attention. x: (B, S, D)."""
    b, s, d = x.shape
    q, k, v = x @ wq, x @ wk, x @ wv
    att = jnp.einsum("bsd,btd->bst", q, k) / jnp.sqrt(d).astype(x.dtype)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bst,btd->bsd", att, v) @ wo


def _moe_ffn_dense(x, wg, w1s, w2s):
    """Soft-mixture MoE FFN over (B, S, D) using the jnp reference
    (differentiable twin of the Pallas path)."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    gates = jax.nn.softmax(flat @ wg, axis=-1)  # (T, E)
    ys = jnp.stack(
        [moe_ffn_ref(flat, w1s[i], w2s[i]) for i in range(wg.shape[1])]
    )  # (E, T, D)
    out = jnp.einsum("ktd,tk->td", ys, gates)
    return out.reshape(b, s, d)


def _rms_norm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def lm_loss(params, tokens, targets, cfg: LmConfig):
    """Next-token cross-entropy of the tiny MoE transformer.

    params: canonical flat list; tokens/targets: (B, S) int32.
    """
    it = iter(params)
    embed = next(it)
    x = embed[tokens]  # (B, S, D)
    for _ in range(cfg.n_layers):
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        wg, w1s, w2s = next(it), next(it), next(it)
        x = x + _causal_attention(_rms_norm(x), wq, wk, wv, wo)
        x = x + _moe_ffn_dense(_rms_norm(x), wg, w1s, w2s)
    unembed = next(it)
    logits = _rms_norm(x) @ unembed  # (B, S, V)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def train_step(params, tokens, targets, cfg: LmConfig):
    """One fused fwd+bwd+SGD step → (loss, new_params...)."""
    loss, grads = jax.value_and_grad(lm_loss)(params, tokens, targets, cfg)
    new_params = [p - cfg.lr * g for p, g in zip(params, grads)]
    return (loss, *new_params)


def make_train_step(cfg: LmConfig):
    """Positional-arg wrapper for AOT lowering: (tokens, targets,
    *params) → (loss, *new_params)."""

    def fn(tokens, targets, *params):
        return train_step(list(params), tokens, targets, cfg)

    return fn


def synthetic_batch(key, cfg: LmConfig):
    """Synthetic 'copy-with-shift' corpus: sequences follow a fixed
    random bigram table, so a competent LM drives loss well below
    ln(vocab) — giving the e2e example a meaningful loss curve."""
    k1, k2 = jax.random.split(key)
    table = jax.random.randint(k1, (cfg.vocab,), 0, cfg.vocab, jnp.int32)
    start = jax.random.randint(k2, (cfg.batch, 1), 0, cfg.vocab, jnp.int32)

    def step(tok, _):
        nxt = table[tok]
        return nxt, nxt

    _, seq = jax.lax.scan(step, start[:, 0], None, length=cfg.seq)
    toks = jnp.concatenate([start, seq.T], axis=1)  # (B, S+1)
    return toks[:, :-1], toks[:, 1:]
