"""AOT bridge: lower the L2 graphs to HLO **text** + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts written to --out:
  expert_ffn_t{T}.hlo.txt     Fig 8 compute buckets (Pallas kernel)
  moe_block_fwd.hlo.txt       quickstart MoE block (both kernels)
  train_step.hlo.txt          e2e training step (fwd+bwd+SGD)
  manifest.json               input/output shapes + model config

Usage: ``python -m compile.aot --out ../artifacts`` (via `make
artifacts`; runs once, never on the request path).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def lower_expert_ffn(out_dir, manifest, tokens_buckets, d_model, d_ff):
    for t in tokens_buckets:
        name = f"expert_ffn_t{t}"
        x = jax.ShapeDtypeStruct((t, d_model), jnp.float32)
        w1 = jax.ShapeDtypeStruct((d_model, d_ff), jnp.float32)
        w2 = jax.ShapeDtypeStruct((d_ff, d_model), jnp.float32)
        lowered = jax.jit(M.expert_ffn).lower(x, w1, w2)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                spec((t, d_model)),
                spec((d_model, d_ff)),
                spec((d_ff, d_model)),
            ],
            "outputs": [spec((t, d_model))],
            "tokens": t,
            "d_model": d_model,
            "d_ff": d_ff,
        }


def lower_moe_block(out_dir, manifest, t, d_model, d_ff, n_experts):
    name = "moe_block_fwd"
    x = jax.ShapeDtypeStruct((t, d_model), jnp.float32)
    wg = jax.ShapeDtypeStruct((d_model, n_experts), jnp.float32)
    w1s = jax.ShapeDtypeStruct((n_experts, d_model, d_ff), jnp.float32)
    w2s = jax.ShapeDtypeStruct((n_experts, d_ff, d_model), jnp.float32)
    lowered = jax.jit(M.moe_block_fwd).lower(x, wg, w1s, w2s)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "inputs": [
            spec((t, d_model)),
            spec((d_model, n_experts)),
            spec((n_experts, d_model, d_ff)),
            spec((n_experts, d_ff, d_model)),
        ],
        "outputs": [spec((t, d_model))],
        "tokens": t,
        "d_model": d_model,
        "d_ff": d_ff,
        "n_experts": n_experts,
    }


def lower_train_step(out_dir, manifest, cfg: M.LmConfig):
    name = "train_step"
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    targets = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.param_specs]
    lowered = jax.jit(M.make_train_step(cfg)).lower(tokens, targets, *params)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "inputs": [spec((cfg.batch, cfg.seq), "i32")] * 2
        + [spec(s) for _, s in cfg.param_specs],
        "outputs": [spec(())] + [spec(s) for _, s in cfg.param_specs],
        "params": [
            {"name": n, "shape": list(s)} for n, s in cfg.param_specs
        ],
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "d_ff": cfg.d_ff,
            "n_experts": cfg.n_experts,
            "n_layers": cfg.n_layers,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "lr": cfg.lr,
            "param_count": cfg.param_count(),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--ffn-buckets", default="256,512,1024,2048,4096")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--d-ff", type=int, default=2048)
    ap.add_argument("--block-experts", type=int, default=4)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "artifacts": {}}

    buckets = [int(x) for x in args.ffn_buckets.split(",")]
    lower_expert_ffn(args.out, manifest, buckets, args.d_model, args.d_ff)
    lower_moe_block(args.out, manifest, 1024, args.d_model, args.d_ff,
                    args.block_experts)
    lower_train_step(args.out, manifest, M.LmConfig())

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
