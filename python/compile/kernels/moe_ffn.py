"""L1 Pallas kernel: the MoE expert FFN hot spot.

Computes ``y = gelu(x @ w1) @ w2`` (the paper's "two-layer FFN with 4x
expansion", §V-D) as a tiled Pallas kernel.

TPU-oriented tiling (DESIGN.md §Hardware-Adaptation): the grid is
(T/bm, F/bf) — token blocks × hidden blocks. Each step keeps one
(bm, D) activation tile, one (D, bf) w1 tile and one (bf, D) w2 tile in
VMEM, drives the MXU with two matmuls, and accumulates partial outputs
in the (bm, D) output tile. gelu is applied per hidden block, which is
exact because each h-block's D-reduction completes within the step.

Run with ``interpret=True`` everywhere: the CPU PJRT backend cannot
execute Mosaic custom-calls; interpret mode lowers to plain HLO so the
same program runs under the rust runtime (see aot.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One grid step: partial FFN over a (token-block, hidden-block)."""
    # initialize the output accumulator on the first hidden block
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]          # (bm, D)
    w1 = w1_ref[...]        # (D, bf)
    w2 = w2_ref[...]        # (bf, D)
    h = jax.nn.gelu(jnp.dot(x, w1))      # (bm, bf) — MXU matmul 1
    o_ref[...] += jnp.dot(h, w2)         # (bm, D)  — MXU matmul 2


@functools.partial(jax.jit, static_argnames=("block_m", "block_f"))
def moe_ffn(x, w1, w2, *, block_m=128, block_f=512):
    """Tiled Pallas expert FFN.

    Args:
      x:  (T, D) tokens routed to this expert.
      w1: (D, F) up-projection.
      w2: (F, D) down-projection.
      block_m: token tile (grid dim 0).
      block_f: hidden tile (grid dim 1).

    Returns: (T, D) expert output, f32.
    """
    t, d = x.shape
    d2, f = w1.shape
    assert d2 == d, f"w1 shape {w1.shape} mismatches x {x.shape}"
    assert w2.shape == (f, d), f"w2 shape {w2.shape}"
    bm = min(block_m, t)
    bf = min(block_f, f)
    assert t % bm == 0, f"tokens {t} not divisible by block_m {bm}"
    assert f % bf == 0, f"hidden {f} not divisible by block_f {bf}"
    grid = (t // bm, f // bf)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),   # x: token tile
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),   # w1: hidden tile
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),   # w2: hidden tile
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w1.astype(jnp.float32), w2.astype(jnp.float32))


def vmem_estimate_bytes(d, block_m=128, block_f=512, elem=4):
    """Static VMEM footprint of one grid step (DESIGN.md §7): the
    x-tile, w1-tile, w2-tile, h-tile and output accumulator."""
    return elem * (
        block_m * d        # x tile
        + d * block_f      # w1 tile
        + block_f * d      # w2 tile
        + block_m * block_f  # h
        + block_m * d      # out accumulator
    )


def mxu_flops(t, d, f):
    """MXU FLOPs for one expert call (2 matmuls)."""
    return 2 * t * d * f * 2
