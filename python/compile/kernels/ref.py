"""Pure-jnp oracles for the Pallas kernels.

pytest compares every kernel against these references across a
hypothesis-driven sweep of shapes and dtypes — the core L1
correctness signal (the kernels and these functions share no code).
"""

import jax
import jax.numpy as jnp


def moe_ffn_ref(x, w1, w2):
    """y = gelu(x @ w1) @ w2 in f32."""
    x = x.astype(jnp.float32)
    w1 = w1.astype(jnp.float32)
    w2 = w2.astype(jnp.float32)
    return jax.nn.gelu(x @ w1) @ w2


def combine_topk_ref(ys, gates):
    """out[t] = sum_k gates[t, k] * ys[k, t]."""
    ys = ys.astype(jnp.float32)
    gates = gates.astype(jnp.float32)
    return jnp.einsum("ktd,tk->td", ys, gates)


def top1_gating_ref(logits):
    """softmax gate + argmax expert (no capacity cap — the paper's
    no-token-dropping deployment, §V-D)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    expert = jnp.argmax(probs, axis=-1)
    gate = jnp.take_along_axis(probs, expert[:, None], axis=-1)[:, 0]
    return expert, gate
