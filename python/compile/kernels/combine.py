"""L1 Pallas kernel: top-k weighted combine.

After experts process their tokens, each token's k expert outputs are
summed with its gate weights: ``out[t] = Σ_k gates[t,k] · ys[k,t]``.
This is the compute half of the paper's "combine" phase (the comm half
is the transpose All-to-Allv, orchestrated at L3).

TPU mapping: the CUDA implementation scatters with warp-level atomics;
on TPU we block over tokens and let each grid step do a dense weighted
reduction over the (small) k axis in VMEM — no atomics needed because
each token tile is owned by exactly one step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(ys_ref, gates_ref, o_ref):
    ys = ys_ref[...]        # (k, bm, D)
    gates = gates_ref[...]  # (bm, k)
    # weighted sum over k: (bm, D)
    o_ref[...] = jnp.einsum("kmd,mk->md", ys, gates)


@functools.partial(jax.jit, static_argnames=("block_m",))
def combine_topk(ys, gates, *, block_m=128):
    """Weighted top-k combine.

    Args:
      ys:    (k, T, D) expert outputs aligned per token slot.
      gates: (T, k) gate weights.

    Returns: (T, D) combined tokens, f32.
    """
    k, t, d = ys.shape
    assert gates.shape == (t, k), f"gates {gates.shape} vs ys {ys.shape}"
    bm = min(block_m, t)
    assert t % bm == 0, f"tokens {t} not divisible by block_m {bm}"
    return pl.pallas_call(
        _combine_kernel,
        grid=(t // bm,),
        in_specs=[
            pl.BlockSpec((k, bm, d), lambda i: (0, i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(ys.astype(jnp.float32), gates.astype(jnp.float32))
