"""L2 correctness: MoE block composition, training-step semantics, and
the pallas-vs-jnp twin-path equivalence the e2e example relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels.ref import moe_ffn_ref

jax.config.update("jax_platform_name", "cpu")


def small_cfg():
    return M.LmConfig(vocab=32, d_model=16, d_ff=32, n_experts=2,
                      n_layers=1, seq=16, batch=2, lr=0.1)


def test_param_specs_cover_all_layers():
    cfg = small_cfg()
    names = [n for n, _ in cfg.param_specs]
    assert names[0] == "embed" and names[-1] == "unembed"
    assert sum(1 for n in names if n.startswith("l0.")) == 7
    assert cfg.param_count() == sum(
        int(np.prod(s)) for _, s in cfg.param_specs
    )


def test_moe_block_fwd_matches_dense_reference():
    """The Pallas-kernel MoE block equals the jnp twin path (this is
    the equivalence that lets train_step use the differentiable twin
    while inference artifacts use the kernels)."""
    key = jax.random.PRNGKey(0)
    t, d, f, e = 128, 16, 32, 3
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (t, d))
    wg = jax.random.normal(k2, (d, e))
    w1s = jax.random.normal(k3, (e, d, f)) / 4.0
    w2s = jax.random.normal(k4, (e, f, d)) / 4.0

    got = M.moe_block_fwd(x, wg, w1s, w2s)

    gates = jax.nn.softmax(x @ wg, axis=-1)
    ys = jnp.stack([moe_ffn_ref(x, w1s[i], w2s[i]) for i in range(e)])
    want = jnp.einsum("ktd,tk->td", ys, gates)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_lm_loss_starts_near_uniform():
    cfg = small_cfg()
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    toks, tgts = M.synthetic_batch(jax.random.PRNGKey(2), cfg)
    loss = M.lm_loss(params, toks, tgts, cfg)
    # untrained model ≈ uniform over vocab
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_train_step_reduces_loss():
    cfg = small_cfg()
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    toks, tgts = M.synthetic_batch(jax.random.PRNGKey(2), cfg)
    step = jax.jit(M.make_train_step(cfg))
    losses = []
    for _ in range(30):
        out = step(toks, tgts, *params)
        losses.append(float(out[0]))
        params = list(out[1:])
    assert losses[-1] < losses[0] * 0.8, f"no learning: {losses[:3]} → {losses[-3:]}"


def test_train_step_preserves_shapes():
    cfg = small_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks, tgts = M.synthetic_batch(jax.random.PRNGKey(3), cfg)
    out = M.make_train_step(cfg)(toks, tgts, *params)
    assert out[0].shape == ()
    for p, q in zip(params, out[1:]):
        assert p.shape == q.shape


def test_synthetic_batch_is_learnable_bigram():
    cfg = small_cfg()
    toks, tgts = M.synthetic_batch(jax.random.PRNGKey(7), cfg)
    assert toks.shape == (cfg.batch, cfg.seq)
    assert tgts.shape == (cfg.batch, cfg.seq)
    # deterministic bigram: the same token always maps to one successor
    mapping = {}
    for row_t, row_g in zip(np.asarray(toks), np.asarray(tgts)):
        for a, b in zip(row_t, row_g):
            assert mapping.setdefault(int(a), int(b)) == int(b)
