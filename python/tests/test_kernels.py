"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

hypothesis sweeps shapes and dtypes; assert_allclose against the
reference is the core correctness signal for the kernels that end up
inside every HLO artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.combine import combine_topk
from compile.kernels.moe_ffn import moe_ffn, mxu_flops, vmem_estimate_bytes
from compile.kernels.ref import combine_topk_ref, moe_ffn_ref, top1_gating_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# moe_ffn
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    t_mult=st.integers(1, 4),
    d=st.sampled_from([16, 64, 128]),
    f_mult=st.integers(1, 4),
    bm=st.sampled_from([16, 32, 64]),
    bf=st.sampled_from([16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_ffn_matches_ref(t_mult, d, f_mult, bm, bf, dtype, seed):
    t = bm * t_mult
    f = bf * f_mult
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    x = rand(k1, (t, d), dtype)
    w1 = rand(k2, (d, f), dtype)
    w2 = rand(k3, (f, d), dtype)
    got = moe_ffn(x, w1, w2, block_m=bm, block_f=bf)
    want = moe_ffn_ref(x, w1, w2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_moe_ffn_single_block():
    k = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(k, 3)
    x = rand(k1, (8, 16), jnp.float32)
    w1 = rand(k2, (16, 8), jnp.float32)
    w2 = rand(k3, (8, 16), jnp.float32)
    got = moe_ffn(x, w1, w2, block_m=8, block_f=8)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(moe_ffn_ref(x, w1, w2)),
                               rtol=1e-4, atol=1e-4)


def test_moe_ffn_rejects_bad_shapes():
    x = jnp.zeros((8, 16))
    w1 = jnp.zeros((17, 8))  # mismatched D
    w2 = jnp.zeros((8, 16))
    with pytest.raises(AssertionError):
        moe_ffn(x, w1, w2, block_m=8, block_f=8)


def test_moe_ffn_indivisible_tokens_rejected():
    x = jnp.zeros((10, 16))
    w1 = jnp.zeros((16, 8))
    w2 = jnp.zeros((8, 16))
    with pytest.raises(AssertionError):
        moe_ffn(x, w1, w2, block_m=8, block_f=8)


def test_vmem_estimate_fits_budget():
    # DESIGN.md §7: at paper scale (d_model=4096) the hidden tile must
    # shrink to bf=256 for the step to fit a 16 MB VMEM budget
    assert vmem_estimate_bytes(4096, 128, 256) < 16 * 2**20
    # the repo-default artifact scale (d=512) fits easily at bf=512
    assert vmem_estimate_bytes(512, 128, 512) < 4 * 2**20
    assert mxu_flops(1024, 512, 2048) == 2 * 1024 * 512 * 2048 * 2


# ---------------------------------------------------------------------------
# combine_topk
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    kk=st.integers(1, 8),
    t_mult=st.integers(1, 4),
    d=st.sampled_from([8, 32, 128]),
    bm=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_matches_ref(kk, t_mult, d, bm, seed):
    t = bm * t_mult
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    ys = rand(k1, (kk, t, d), jnp.float32)
    gates = jax.nn.softmax(rand(k2, (t, kk), jnp.float32), axis=-1)
    got = combine_topk(ys, gates, block_m=bm)
    want = combine_topk_ref(ys, gates)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_combine_identity_when_one_expert():
    ys = jnp.arange(64 * 8, dtype=jnp.float32).reshape(1, 64, 8)
    gates = jnp.ones((64, 1), jnp.float32)
    got = combine_topk(ys, gates, block_m=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ys[0]))


# ---------------------------------------------------------------------------
# gating reference sanity
# ---------------------------------------------------------------------------

def test_top1_gating_picks_argmax():
    logits = jnp.array([[0.1, 3.0, -1.0], [2.0, 0.0, 0.0]])
    expert, gate = top1_gating_ref(logits)
    assert expert.tolist() == [1, 0]
    assert (gate > 0.33).all()
