//! Fig 6 driver: point-to-point bandwidth with additional intra-node
//! paths and inter-node rails, plus the forwarding-overhead panels.
//!
//! ```bash
//! cargo run --release --offline --example multirail_p2p -- --part all
//! ```

use nimble::exp::fig6;
use nimble::fabric::FabricParams;
use nimble::topology::Topology;
use nimble::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("multirail_p2p", "Fig 6 panels")
        .flag("part", "all", "a|b|c|d|all")
        .parse(&argv)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("{}", fig6::render(&topo, &params, args.get("part")));
}
