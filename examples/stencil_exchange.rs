//! Table I driver: 1-D stencil neighbor exchange — planner overhead vs
//! communication time — plus the boundary-hotspot variant (§III-A-c)
//! showing the adaptive orchestrator reacting over rounds.
//!
//! ```bash
//! cargo run --release --offline --example stencil_exchange
//! ```

use nimble::coordinator::Orchestrator;
use nimble::exp::{table1, MB};
use nimble::fabric::FabricParams;
use nimble::topology::Topology;
use nimble::workloads::stencil::stencil_1d_hotspot;

fn main() {
    let topo = Topology::paper();
    let params = FabricParams::default();

    println!("{}", table1::render(&topo, &params, 9));

    // adaptive multi-round run on a boundary-hotspot stencil: the
    // monitor's EWMA feeds each round's plan
    println!("\nadaptive orchestrator on boundary-hotspot stencil (4× heavier middle):");
    let mut orch = Orchestrator::new(&topo, params);
    let demands = stencil_1d_hotspot(&topo, 32.0 * MB, 4.0);
    for round in 0..5 {
        let out = orch.run_round(&demands);
        println!(
            "  round {round}: makespan {:.3} ms, peak link util {:.0}%, links used {}, reassembly peak {}",
            out.report.makespan_s * 1e3,
            out.report.peak_link_util * 100.0,
            out.report.links_used,
            out.peak_reassembly,
        );
    }
    println!(
        "  channel staging memory: {:.1} MB (constant across rounds — §IV-D)",
        orch.channels.total_buffer_bytes() / (1024.0 * 1024.0)
    );
}
