//! End-to-end driver: trains the tiny MoE-transformer LM via the AOT
//! `train_step` artifact (JAX fwd+bwd+SGD → HLO → PJRT-CPU, executed
//! from rust) on a synthetic bigram corpus, logging the loss curve —
//! while NIMBLE simulates the expert-parallel dispatch/combine the
//! same layers would incur on the paper's 8-GPU cluster, reporting
//! per-step communication under NCCL vs NIMBLE.
//!
//! This is the "all layers compose" proof: L1 Pallas kernels (inside
//! the inference artifacts), L2 JAX training graph, L3 coordinator —
//! one binary, no Python.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example moe_e2e -- --steps 150
//! ```

use nimble::baselines::NcclLike;
use nimble::coordinator::NimbleRouter;
use nimble::fabric::FabricParams;
use nimble::moe::run_moe_step;
use nimble::runtime::{ComputeModel, Runtime};
use nimble::topology::Topology;
use nimble::util::cli::Args;
use nimble::util::rng::Rng;
use nimble::workloads::moe_traffic::MoeConfig;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("moe_e2e", "train the MoE LM through PJRT artifacts")
        .flag("steps", "150", "training steps")
        .flag("seed", "42", "init/data seed")
        .flag("log-every", "10", "loss log cadence")
        .parse(&argv)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let steps: usize = args.get_usize("steps");
    let seed = args.get_u64("seed");
    let log_every = args.get_usize("log-every").max(1);

    let mut rt = Runtime::open(Runtime::default_dir())?;
    let info = rt.artifact_info("train_step");
    let cfg = info.get("config");
    let (vocab, seq, batch) = (
        cfg.get("vocab").as_u64().unwrap() as usize,
        cfg.get("seq").as_u64().unwrap() as usize,
        cfg.get("batch").as_u64().unwrap() as usize,
    );
    let param_count = cfg.get("param_count").as_u64().unwrap();
    println!(
        "model: {} params, vocab {vocab}, seq {seq}, batch {batch} (see manifest.json)",
        param_count
    );

    // ---- init params in-rust from the manifest's canonical specs ----
    let mut rng = Rng::new(seed);
    let specs: Vec<(String, Vec<usize>)> = info
        .get("params")
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| {
            let name = p.get("name").as_str().unwrap().to_string();
            let shape: Vec<usize> = p
                .get("shape")
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_u64().unwrap() as usize)
                .collect();
            (name, shape)
        })
        .collect();
    let mut params: Vec<xla::Literal> = specs
        .iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product();
            let fan_in = if shape.len() >= 2 { shape[shape.len() - 2] } else { shape[0] };
            let scale = 1.0 / (fan_in as f64).sqrt();
            let data: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            Runtime::literal_f32(&data, &dims).unwrap()
        })
        .collect();

    // ---- synthetic bigram corpus (learnable: fixed successor table) ----
    let table: Vec<i32> = (0..vocab).map(|_| rng.below(vocab as u64) as i32).collect();
    let make_batch = |rng: &mut Rng| {
        let mut toks = vec![0i32; batch * seq];
        let mut tgts = vec![0i32; batch * seq];
        for b in 0..batch {
            let mut cur = rng.below(vocab as u64) as i32;
            for s in 0..seq {
                toks[b * seq + s] = cur;
                let nxt = table[cur as usize];
                tgts[b * seq + s] = nxt;
                cur = nxt;
            }
        }
        (
            Runtime::literal_i32(&toks, &[batch as i64, seq as i64]).unwrap(),
            Runtime::literal_i32(&tgts, &[batch as i64, seq as i64]).unwrap(),
        )
    };

    // ---- EP-deployment comm simulation alongside training ----
    let topo = Topology::paper();
    let fp = FabricParams::default();
    let cm = ComputeModel::default();
    let moe_cfg = MoeConfig::paper(16_384, 0.8);
    let nccl_step = run_moe_step(&topo, &fp, &cm, &mut NcclLike::new(), &moe_cfg);
    let nim_step =
        run_moe_step(&topo, &fp, &cm, &mut NimbleRouter::default_for(&topo), &moe_cfg);

    // ---- training loop ----
    println!("\nstep   loss      step-time   (simulated EP comm/step: nccl {:.2} ms → nimble {:.2} ms)",
        (nccl_step.dispatch_s + nccl_step.combine_s) * 1e3,
        (nim_step.dispatch_s + nim_step.combine_s) * 1e3);
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        let (toks, tgts) = make_batch(&mut rng);
        let mut inputs = Vec::with_capacity(2 + params.len());
        inputs.push(toks);
        inputs.push(tgts);
        inputs.extend(params.drain(..));
        let t0 = std::time::Instant::now();
        let mut out = rt.execute("train_step", &inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        let loss = out.remove(0).to_vec::<f32>()?[0];
        params = out; // new params
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        if step % log_every == 0 || step + 1 == steps {
            println!("{step:>4}   {loss:<8.4}  {:>7.1} ms", dt * 1e3);
        }
    }
    let first = first_loss.unwrap();
    println!(
        "\nloss: {first:.4} → {last_loss:.4} over {steps} steps \
         (uniform baseline ln({vocab}) = {:.4})",
        (vocab as f64).ln()
    );
    anyhow::ensure!(
        last_loss < first * 0.7,
        "training did not converge: {first} → {last_loss}"
    );
    println!("e2e OK: L1 kernels (artifacts) + L2 train graph + L3 coordinator compose.");
    Ok(())
}
