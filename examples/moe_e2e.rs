//! End-to-end driver: runs the AOT expert-FFN artifacts (JAX/Pallas →
//! HLO text + manifest, executed through the crate's offline
//! interpreter runtime) — while NIMBLE simulates the expert-parallel
//! dispatch/combine the same layers would incur on the paper's 8-GPU
//! cluster, reporting per-step communication under NCCL vs NIMBLE.
//!
//! This is the "all layers compose" proof for the offline build: L1/L2
//! artifact math (manifest-driven FFN) + L3 coordinator — one binary,
//! no Python on the execution path. The `train_step` artifact
//! (fwd+bwd+SGD) needs the PJRT-enabled build and is reported, not run.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example moe_e2e
//! ```

use nimble::baselines::NcclLike;
use nimble::coordinator::NimbleRouter;
use nimble::fabric::FabricParams;
use nimble::moe::run_moe_step;
use nimble::runtime::{ComputeModel, Literal, Runtime};
use nimble::topology::Topology;
use nimble::util::cli::Args;
use nimble::util::rng::Rng;
use nimble::workloads::moe_traffic::MoeConfig;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("moe_e2e", "run the MoE FFN artifacts + EP comm simulation")
        .flag("seed", "42", "input data seed")
        .flag("tokens", "16384", "global tokens per EP step (simulation)")
        .flag("hotspot", "0.8", "gating hotspot ratio (simulation)")
        .parse(&argv)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let seed = args.get_u64("seed");
    let tokens = args.get_usize("tokens");
    let hotspot = args.get_f64("hotspot");

    // ---- EP-deployment comm simulation (always available) ----
    let topo = Topology::paper();
    let fp = FabricParams::default();
    let cm = ComputeModel::default();
    let moe_cfg = MoeConfig::paper(tokens, hotspot);
    let nccl_step = run_moe_step(&topo, &fp, &cm, &mut NcclLike::new(), &moe_cfg);
    let nim_step =
        run_moe_step(&topo, &fp, &cm, &mut NimbleRouter::default_for(&topo), &moe_cfg);
    println!(
        "simulated EP step ({} tokens, hotspot {:.2}) on the paper's 8-GPU cluster:"
        , tokens, hotspot
    );
    println!(
        "  nccl   : dispatch {:.3} ms + compute {:.3} ms + combine {:.3} ms = {:.3} ms",
        nccl_step.dispatch_s * 1e3,
        nccl_step.compute_s * 1e3,
        nccl_step.combine_s * 1e3,
        nccl_step.total_s() * 1e3
    );
    println!(
        "  nimble : dispatch {:.3} ms + compute {:.3} ms + combine {:.3} ms = {:.3} ms  ({:.2}x)",
        nim_step.dispatch_s * 1e3,
        nim_step.compute_s * 1e3,
        nim_step.combine_s * 1e3,
        nim_step.total_s() * 1e3,
        nccl_step.total_s() / nim_step.total_s()
    );

    // ---- real artifact execution through the offline runtime ----
    let dir = Runtime::default_dir();
    let mut rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("\nartifacts not available ({e})");
            println!("run `make artifacts` to lower the JAX/Pallas graphs, then re-run.");
            println!("comm simulation completed; exiting.");
            return;
        }
    };
    println!("\nartifacts: {:?}", rt.artifact_names());
    let mut rng = Rng::new(seed);
    let mut ran = 0usize;
    for name in rt.artifact_names() {
        if !rt.supports(&name) {
            println!("{name}: skipped (needs the PJRT-enabled build)");
            continue;
        }
        let info = rt.artifact_info(&name);
        let n_inputs = info.get("inputs").as_arr().map(|a| a.len()).unwrap_or(0);
        let (Some(t), Some(d), Some(f)) = (
            info.get("tokens").as_u64().map(|x| x as usize),
            info.get("d_model").as_u64().map(|x| x as usize),
            info.get("d_ff").as_u64().map(|x| x as usize),
        ) else {
            println!("{name}: skipped (manifest lacks shape metadata)");
            continue;
        };
        let n_experts = info.get("n_experts").as_u64().map(|x| x as usize);
        let scale = 1.0 / (d as f64).sqrt();
        let mut tensor = |dims: &[usize]| -> Literal {
            let n: usize = dims.iter().product();
            let data: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
            let dims: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
            Runtime::literal_f32(&data, &dims).unwrap()
        };
        // dispatch on the manifest arity (the same signal the
        // interpreter keys on): 3 = expert FFN, 4 = gated MoE block
        let inputs: Vec<Literal> = match (n_inputs, n_experts) {
            (3, _) => vec![tensor(&[t, d]), tensor(&[d, f]), tensor(&[f, d])],
            (4, Some(e)) => vec![
                tensor(&[t, d]),
                tensor(&[d, e]),
                tensor(&[e, d, f]),
                tensor(&[e, f, d]),
            ],
            _ => {
                println!("{name}: skipped (unrecognized input arity {n_inputs})");
                continue;
            }
        };
        let t0 = std::time::Instant::now();
        match rt.execute(&name, &inputs) {
            Ok(out) => {
                let dt = t0.elapsed().as_secs_f64();
                let y = out[0].to_vec::<f32>().unwrap();
                let norm: f64 = y.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
                assert!(norm.is_finite(), "{name}: non-finite output");
                let kind = if inputs.len() == 4 { "gated MoE block" } else { "expert FFN" };
                println!(
                    "{name}: {t}x{d} tokens through the {kind} ({d}->{f}->{d}) in {:.1} ms (||y|| = {norm:.3})",
                    dt * 1e3
                );
                ran += 1;
            }
            Err(e) => println!("{name}: {e}"),
        }
    }
    if ran > 0 {
        println!("\ne2e OK: L1/L2 artifact math + L3 coordinator compose in one binary.");
    }
}
