//! Quickstart: plan and execute one skewed All-to-Allv round with
//! NIMBLE vs NCCL on the paper's 2-node × 4-GPU testbed.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use nimble::baselines::NcclLike;
use nimble::collectives::alltoallv::alltoallv_demands;
use nimble::coordinator::NimbleRouter;
use nimble::fabric::FabricParams;
use nimble::topology::Topology;
use nimble::workloads::skew::hotspot_alltoallv;

const MB: f64 = 1024.0 * 1024.0;

fn main() {
    // 1. the paper's testbed: 2 nodes × (4× H100 + 4× NDR rails)
    let topo = Topology::paper();
    let params = FabricParams::default();
    println!("cluster: {} GPUs, {} directed links", topo.num_gpus(), topo.links.len());

    // 2. a skewed workload: every rank sends 64 MB, 80% of it to GPU 4
    let demands = hotspot_alltoallv(&topo, 64.0 * MB, 0.8, 4);
    println!("workload: {} messages, hotspot = GPU 4 @ 80%", demands.len());

    // 3. run under NCCL-like static routing vs NIMBLE
    let mut nccl = NcclLike::new();
    let nccl_report = alltoallv_demands(&topo, &params, &mut nccl, &demands);

    let mut nimble = NimbleRouter::default_for(&topo);
    let nimble_report = alltoallv_demands(&topo, &params, &mut nimble, &demands);

    println!("\n{:<10} {:>12} {:>14} {:>12} {:>10}",
             "engine", "makespan", "goodput GB/s", "peak util", "links");
    for r in [&nccl_report, &nimble_report] {
        println!(
            "{:<10} {:>9.3} ms {:>14.1} {:>11.0}% {:>10}",
            r.engine,
            r.makespan_s * 1e3,
            r.goodput_gbps(),
            r.peak_link_util * 100.0,
            r.links_used
        );
    }
    let speedup = nccl_report.makespan_s / nimble_report.makespan_s;
    println!("\nNIMBLE speedup vs NCCL: {speedup:.2}×");

    // 4. inspect the plan the coordinator produced for the hot pair
    let plan = nimble.last_plan.as_ref().unwrap();
    println!("\nhot-pair (0→4) flow split:");
    for (path, bytes) in &plan.assignments[&(0, 4)].parts {
        println!("  {:>7.1} MB via {:?}", bytes / MB, path.kind);
    }
}
