//! Fig 7 driver: skewed All-to-Allv sweep over hotspot ratios and
//! payload sizes under all three engines (NCCL / OpenMPI / NIMBLE),
//! including the tail-latency view the paper motivates (§I: "severe
//! increase in tail latencies (p99)").
//!
//! ```bash
//! cargo run --release --offline --example skewed_alltoallv -- --payload-mb 64
//! ```

use nimble::baselines::{MpiLike, NcclLike, Router};
use nimble::collectives::alltoallv::alltoallv_demands;
use nimble::coordinator::NimbleRouter;
use nimble::exp::MB;
use nimble::fabric::FabricParams;
use nimble::metrics::Table;
use nimble::topology::Topology;
use nimble::util::cli::Args;
use nimble::workloads::skew::hotspot_alltoallv;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("skewed_alltoallv", "Fig 7 driver with tail latency")
        .flag("payload-mb", "64", "per-rank payload (MB)")
        .flag("hot", "4", "hot destination GPU")
        .parse(&argv)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let payload = args.get_f64("payload-mb") * MB;
    let hot = args.get_usize("hot");

    let topo = Topology::paper();
    let params = FabricParams::default();

    let mut t = Table::new(&[
        "hotspot", "engine", "makespan (ms)", "p50 (ms)", "p99 (ms)", "fairness",
    ]);
    for ratio in [0.125, 0.3, 0.5, 0.7, 0.9] {
        let demands = hotspot_alltoallv(&topo, payload, ratio, hot);
        let engines: Vec<Box<dyn Router>> = vec![
            Box::new(NcclLike::new()),
            Box::new(MpiLike::new()),
            Box::new(NimbleRouter::default_for(&topo)),
        ];
        for mut e in engines {
            let r = alltoallv_demands(&topo, &params, e.as_mut(), &demands);
            let s = r.latency_summary();
            t.row(&[
                format!("{ratio:.3}"),
                r.engine.clone(),
                format!("{:.3}", r.makespan_s * 1e3),
                format!("{:.3}", s.p50 * 1e3),
                format!("{:.3}", s.p99 * 1e3),
                format!("{:.3}", r.link_fairness),
            ]);
        }
    }
    println!("{}", t.render());
    println!("fairness = Jain index over busy-link utilization (1.0 = perfectly balanced)");
}
